//! The HTTP/1.1 front door: a dependency-free network layer between the
//! OS and the ticketed [`Engine`](crate::serve::Engine).
//!
//! ## Architecture
//!
//! ```text
//! clients ──▶ TcpListener ──▶ acceptor thread ──▶ [conn queue] bounded
//!                                                      │ pop
//!                                  conn worker 0 … N-1 (exec::ThreadPool)
//!                                        │ keep-alive loop per connection
//!                                     Router ──▶ handlers
//!                                        │
//!                         POST /v1/infer ──▶ Engine::try_submit_classed
//!                         GET  /metrics  ──▶ obs::prom::render
//!                         GET  /healthz  ──▶ ok | degraded | draining
//! ```
//!
//! Everything is `std::net` + the repo's own primitives (the vendored
//! crate set has no tokio): a blocking acceptor thread feeds accepted
//! sockets into a bounded [`Bounded<TcpStream>`] queue drained by
//! `conn_workers` threads, each running the keep-alive loop in
//! [`conn`]. When the connection queue is full the acceptor answers 503
//! inline — bounded memory at any accept rate, same philosophy as the
//! engine's admission queue.
//!
//! ## Wire format (`POST /v1/infer`)
//!
//! Request: `{"tokens": [1, 2, ...], "class": "interactive" |
//! "batch" | "best_effort", "deadline_us": 2000}` — `class` defaults to
//! `interactive`, `deadline_us` to the engine config's default (0 opts
//! out explicitly). Response 200: `{"id", "prediction", "logits",
//! "class", "queue_us", "exec_us", "latency_us", "batch_size"}`. Errors
//! are JSON too: 400 `bad_request` (with a `reason`), 503 `queue_full` /
//! `class_share_exceeded` / `draining` / `preempted`, 504
//! `deadline_exceeded`, 500 `worker_failed`.
//!
//! ## Class shares
//!
//! The `[http] class_share` knobs gate admission *at the front door*:
//! class `c` is turned away (503, counted in the engine's per-class
//! rejected slice) once its queue occupancy reaches
//! `share[c] × queue_depth`. This keeps lower classes from filling the
//! queue in the first place; the EDF queue's preemption handles whatever
//! still collides inside.

pub mod conn;
pub mod router;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::exec::ThreadPool;
use crate::obs::prom::{render, Sources};
use crate::resil;
use crate::util::json::Json;

use super::class::Class;
use super::engine::Engine;
use super::queue::Bounded;
use super::ticket::{AdmissionError, ServeError};

pub use conn::{Conn, HttpLimits, HttpRequest, HttpResponse, ParseError};
pub use router::Router;

/// The `[http]` config table: front-door address, connection workers,
/// protocol limits, and per-class queue shares.
#[derive(Debug, Clone, PartialEq)]
pub struct HttpConfig {
    /// Bind address for the front door (`host:port`; port 0 = ephemeral).
    /// `None` disables the HTTP server (in-process serving only).
    pub addr: Option<String>,
    /// Connection-worker threads. `0` = one per core.
    pub conn_workers: usize,
    /// Requests served per connection before the server closes it.
    pub keepalive_requests: usize,
    /// Close a connection idle for this long between requests, ms.
    pub idle_timeout_ms: u64,
    /// Max bytes of request line + headers (431 beyond).
    pub max_header_bytes: usize,
    /// Max request body bytes (413 beyond).
    pub max_body_bytes: usize,
    /// Per-class admission-queue share, indexed by [`Class::index`]: class
    /// `c` is 503'd at the front door once it occupies
    /// `class_share[c] × queue_depth` slots. Interactive conventionally
    /// 1.0 (never gated).
    pub class_share: [f64; Class::COUNT],
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            addr: None,
            conn_workers: 4,
            keepalive_requests: 256,
            idle_timeout_ms: 5_000,
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1024 * 1024,
            class_share: [1.0, 0.9, 0.75],
        }
    }
}

impl HttpConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.keepalive_requests == 0 {
            return Err("http.keepalive_requests must be ≥ 1".into());
        }
        if self.idle_timeout_ms == 0 {
            return Err("http.idle_timeout_ms must be ≥ 1 (0 would close every connection)".into());
        }
        if self.max_header_bytes < 256 {
            return Err("http.max_header_bytes must be ≥ 256 (a request line barely fits)".into());
        }
        if self.max_body_bytes == 0 {
            return Err("http.max_body_bytes must be ≥ 1".into());
        }
        for c in Class::ALL {
            let s = self.class_share[c.index()];
            if !(s > 0.0 && s <= 1.0) || !s.is_finite() {
                return Err(format!(
                    "http.class_share for {c} must be in (0, 1], got {s}"
                ));
            }
        }
        Ok(())
    }

    pub fn limits(&self) -> HttpLimits {
        HttpLimits {
            max_header_bytes: self.max_header_bytes,
            max_body_bytes: self.max_body_bytes,
            keepalive_requests: self.keepalive_requests,
            idle_timeout: Duration::from_millis(self.idle_timeout_ms),
        }
    }

    /// `conn_workers` with `0` resolved to the core count.
    pub fn resolved_conn_workers(&self) -> usize {
        crate::exec::ExecConfig::with_workers(self.conn_workers).resolved_workers()
    }
}

/// The running HTTP server: acceptor thread + conn-worker pool.
/// [`HttpServer::stop`] is graceful: stop accepting, finish in-flight
/// requests (keep-alive loops close after their current response), join.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conn_q: Arc<Bounded<TcpStream>>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    pool: Option<ThreadPool>,
}

impl HttpServer {
    /// Bind `addr` and start serving `router`. The listener is blocking;
    /// `stop()` wakes it with a self-connection.
    pub fn start(addr: &str, cfg: &HttpConfig, router: Router) -> std::io::Result<Self> {
        if let Err(e) = cfg.validate() {
            return Err(std::io::Error::new(std::io::ErrorKind::InvalidInput, e));
        }
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let workers = cfg.resolved_conn_workers();
        let stop = Arc::new(AtomicBool::new(false));
        let conn_q = Arc::new(Bounded::<TcpStream>::new(4 * workers));
        let router = Arc::new(router);
        let limits = cfg.limits();

        let acceptor = {
            let stop = stop.clone();
            let conn_q = conn_q.clone();
            std::thread::Builder::new()
                .name("spion-http-accept".into())
                .spawn(move || accept_loop(listener, conn_q, stop))?
        };

        let pool = ThreadPool::new(workers);
        for _ in 0..workers {
            let conn_q = conn_q.clone();
            let router = router.clone();
            let stop = stop.clone();
            pool.submit(move |_wid| {
                while let Some(stream) = conn_q.pop() {
                    handle_connection(stream, &router, limits, &stop);
                }
            });
        }

        Ok(Self { addr: local, stop, conn_q, acceptor: Some(acceptor), pool: Some(pool) })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain: stop accepting, let every in-flight request finish
    /// (keep-alive loops close after their current response), join all
    /// threads. Idempotent; also runs on drop.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept with a throwaway self-connection; the
        // acceptor re-checks the flag per iteration.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // No new connections can arrive; close the queue so workers exit
        // once the backlog (including any in-flight keep-alive loop, which
        // polls the stop flag) drains.
        self.conn_q.close();
        self.pool.take(); // ThreadPool::drop joins the workers
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, conn_q: Arc<Bounded<TcpStream>>, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                // Transient accept failure (e.g. fd pressure): back off
                // briefly instead of spinning hot.
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
        };
        if stop.load(Ordering::Relaxed) {
            // Raced with shutdown (possibly the wake-up self-connection).
            return;
        }
        if let Err(e) = conn_q.try_push(stream) {
            // Connection queue full (or closed): shed at the socket with a
            // best-effort 503 so the client fails fast instead of hanging.
            let stream = match e {
                super::queue::TryPushError::Full(s) | super::queue::TryPushError::Closed(s) => s,
            };
            if let Ok(mut c) = Conn::new(
                stream,
                HttpLimits {
                    max_header_bytes: 1024,
                    max_body_bytes: 0,
                    keepalive_requests: 1,
                    idle_timeout: Duration::from_millis(100),
                },
            ) {
                let resp = HttpResponse::json(
                    503,
                    error_json("overloaded", "connection queue full"),
                )
                .with_retry_after(1);
                let _ = c.write_response(&resp, false);
            }
        }
    }
}

/// Per-connection keep-alive loop: parse → dispatch → respond, until the
/// client closes, a limit trips, or the server drains.
fn handle_connection(
    stream: TcpStream,
    router: &Router,
    limits: HttpLimits,
    stop: &AtomicBool,
) {
    let Ok(mut conn) = Conn::new(stream, limits) else {
        return;
    };
    let mut served = 0usize;
    loop {
        match conn.read_request(stop) {
            Ok(req) => {
                served += 1;
                let resp = router.dispatch(&req);
                // Drain or the per-connection cap ⇒ announce close; the
                // client's own preference is honored otherwise.
                let keep_alive = req.wants_keep_alive()
                    && served < limits.keepalive_requests
                    && !stop.load(Ordering::Relaxed);
                if conn.write_response(&resp, keep_alive).is_err() || !keep_alive {
                    return;
                }
            }
            Err(ParseError::Bad { status, reason }) => {
                // Framing can't be trusted past a protocol error: answer
                // and close.
                let resp = HttpResponse::json(status, error_json("bad_request", &reason));
                let _ = conn.write_response(&resp, false);
                return;
            }
            Err(ParseError::Eof | ParseError::IdleTimeout | ParseError::Stopped) => return,
            Err(ParseError::Io(_)) => return,
        }
    }
}

fn error_json(error: &str, reason: &str) -> String {
    Json::obj(vec![
        ("error", Json::Str(error.to_string())),
        ("reason", Json::Str(reason.to_string())),
    ])
    .to_string_pretty()
}

/// The full API router: `/v1/infer` + `/metrics` + `/healthz`.
pub fn api_router(engine: Arc<Engine>, sources: Sources, shares: [f64; Class::COUNT]) -> Router {
    let metrics_sources = sources.clone();
    let health = sources.health.clone();
    Router::new()
        .post("/v1/infer", move |req| infer_handler(&engine, shares, req))
        .get("/metrics", move |_| metrics_response(&metrics_sources))
        .get("/healthz", move |_| healthz_response(health.as_ref()))
}

/// The `--metrics-addr` alias router: only `/metrics` + `/healthz` (no
/// inference surface on the observability port).
pub fn metrics_router(sources: Sources) -> Router {
    let health = sources.health.clone();
    Router::new()
        .get("/metrics", move |_| metrics_response(&sources))
        .get("/healthz", move |_| healthz_response(health.as_ref()))
}

fn metrics_response(sources: &Sources) -> HttpResponse {
    HttpResponse {
        status: 200,
        content_type: "text/plain; version=0.0.4",
        body: render(sources).into_bytes(),
        retry_after: None,
    }
}

fn healthz_response(health: Option<&resil::Health>) -> HttpResponse {
    // Always HTTP 200: orchestrators key off the body, and a draining
    // process is healthy enough to say so (same contract as the old
    // obs::http listener).
    let h = health.map(|h| h.load(Ordering::Relaxed)).unwrap_or(resil::HEALTH_OK);
    HttpResponse::text(200, format!("{}\n", resil::health_name(h)))
}

/// Parse + admit + wait: the whole request path for `POST /v1/infer`.
fn infer_handler(engine: &Engine, shares: [f64; Class::COUNT], req: &HttpRequest) -> HttpResponse {
    let parsed = match parse_infer_body(&req.body) {
        Ok(p) => p,
        Err(reason) => return HttpResponse::json(400, error_json("bad_request", &reason)),
    };
    let (tokens, class, deadline_us) = parsed;

    // Class-share gate: turn the class away before it can fill the queue.
    let depth = engine.config().queue_depth;
    let limit = ((shares[class.index()] * depth as f64).floor() as usize).clamp(1, depth);
    if limit < depth && engine.queue_len_class(class) >= limit {
        let stats = engine.stats();
        stats.rejected.fetch_add(1, Ordering::Relaxed);
        stats.class_rejected[class.index()].fetch_add(1, Ordering::Relaxed);
        return HttpResponse::json(
            503,
            error_json("class_share_exceeded", &format!("class {class} is over its queue share")),
        )
        .with_retry_after(1);
    }

    let ticket = match engine.try_submit_classed(tokens, class, deadline_us) {
        Ok(t) => t,
        Err(AdmissionError::QueueFull) => {
            return HttpResponse::json(503, error_json("queue_full", "admission queue full"))
                .with_retry_after(1)
        }
        Err(AdmissionError::ShuttingDown) => {
            return HttpResponse::json(503, error_json("draining", "engine is shutting down"))
        }
        Err(AdmissionError::BadRequest { reason }) => {
            return HttpResponse::json(400, error_json("bad_request", &reason))
        }
    };

    match ticket.wait() {
        Ok(resp) => {
            let body = Json::obj(vec![
                ("id", Json::Num(resp.id as f64)),
                ("prediction", Json::Num(resp.class as f64)),
                ("logits", Json::arr_f32(&resp.logits)),
                ("class", Json::Str(class.name().to_string())),
                ("queue_us", Json::Num(resp.queue_us as f64)),
                ("exec_us", Json::Num(resp.exec_us as f64)),
                ("latency_us", Json::Num(resp.latency.as_micros() as f64)),
                ("batch_size", Json::Num(resp.batch_size as f64)),
            ]);
            HttpResponse::json(200, body.to_string_pretty())
        }
        Err(ServeError::Preempted) => HttpResponse::json(
            503,
            error_json("preempted", "evicted by a higher-priority request"),
        )
        .with_retry_after(1),
        Err(ServeError::DeadlineExceeded) => {
            HttpResponse::json(504, error_json("deadline_exceeded", "deadline expired in queue"))
        }
        Err(ServeError::ShuttingDown) => {
            HttpResponse::json(503, error_json("draining", "engine shut down before execution"))
        }
        Err(ServeError::WorkerFailed { reason }) => {
            HttpResponse::json(500, error_json("worker_failed", &reason))
        }
    }
}

type InferBody = (Vec<i32>, Class, Option<u64>);

/// Validate the infer wire format. Every rejection names the field and
/// what was wrong with it — clients debug from the 400 body alone.
fn parse_infer_body(body: &[u8]) -> Result<InferBody, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid utf-8".to_string())?;
    if text.trim().is_empty() {
        return Err("empty body; expected a json object with a \"tokens\" array".into());
    }
    let v = Json::parse(text).map_err(|e| format!("invalid json: {e}"))?;
    let tokens_v = v.get("tokens").ok_or_else(|| "missing required field \"tokens\"".to_string())?;
    let arr = tokens_v.as_arr().ok_or_else(|| "\"tokens\" must be an array".to_string())?;
    let mut tokens = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let x = t.as_f64().ok_or_else(|| format!("tokens[{i}] is not a number"))?;
        if !x.is_finite() || x.fract() != 0.0 || x < i32::MIN as f64 || x > i32::MAX as f64 {
            return Err(format!("tokens[{i}] = {x} is not an i32 token id"));
        }
        tokens.push(x as i32);
    }
    let class = match v.get("class") {
        None => Class::Interactive,
        Some(c) => {
            let s = c.as_str().ok_or_else(|| "\"class\" must be a string".to_string())?;
            Class::parse(s).ok_or_else(|| {
                format!(
                    "unknown class {s:?}; expected \"interactive\", \"batch\" or \"best_effort\""
                )
            })?
        }
    };
    let deadline_us = match v.get("deadline_us") {
        None => None,
        Some(d) => {
            let x = d.as_f64().ok_or_else(|| "\"deadline_us\" must be a number".to_string())?;
            if !x.is_finite() || x.fract() != 0.0 || x < 0.0 || x > 1e15 {
                return Err(format!("\"deadline_us\" = {x} is not a non-negative integer"));
            }
            Some(x as u64)
        }
    };
    Ok((tokens, class, deadline_us))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn infer_body_parses_full_and_minimal_forms() {
        let (toks, class, dl) =
            parse_infer_body(br#"{"tokens": [0, 1, 2], "class": "batch", "deadline_us": 2500}"#)
                .unwrap();
        assert_eq!(toks, vec![0, 1, 2]);
        assert_eq!(class, Class::Batch);
        assert_eq!(dl, Some(2500));
        let (toks, class, dl) = parse_infer_body(br#"{"tokens": []}"#).unwrap();
        assert!(toks.is_empty());
        assert_eq!(class, Class::Interactive, "class defaults to interactive");
        assert_eq!(dl, None, "deadline defaults to the engine config");
    }

    #[test]
    fn infer_body_rejections_are_descriptive() {
        let cases: [(&[u8], &str); 7] = [
            (b"", "empty body"),
            (b"{nope", "invalid json"),
            (br#"{"class": "batch"}"#, "missing required field"),
            (br#"{"tokens": "abc"}"#, "must be an array"),
            (br#"{"tokens": [1.5]}"#, "not an i32"),
            (br#"{"tokens": [1], "class": "urgent"}"#, "unknown class"),
            (br#"{"tokens": [1], "deadline_us": -5}"#, "non-negative"),
        ];
        for (body, needle) in cases {
            let err = parse_infer_body(body).unwrap_err();
            assert!(err.contains(needle), "body {body:?}: {err}");
        }
        assert!(!parse_infer_body(&[0xff, 0xfe]).unwrap_err().is_empty(), "non-utf8 rejected");
    }

    #[test]
    fn config_validation_catches_degenerate_knobs() {
        assert!(HttpConfig::default().validate().is_ok());
        let bad = HttpConfig { keepalive_requests: 0, ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("keepalive"));
        let bad = HttpConfig { max_header_bytes: 10, ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("max_header_bytes"));
        let bad = HttpConfig { class_share: [1.0, 0.5, 0.0], ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("class_share"));
        let bad = HttpConfig { class_share: [1.0, 1.5, 0.5], ..Default::default() };
        assert!(bad.validate().unwrap_err().contains("class_share"));
    }

    #[test]
    fn error_json_is_parseable() {
        let s = error_json("queue_full", "admission queue full");
        let v = Json::parse(&s).unwrap();
        assert_eq!(v.get("error").unwrap().as_str().unwrap(), "queue_full");
        assert!(v.get("reason").unwrap().as_str().unwrap().contains("queue"));
    }
}
