//! Config-file integration + failure-injection tests: every shipped config
//! parses and resolves; every error path reports a useful message instead
//! of panicking.

use spion::config::types::{load_experiment, preset};
use spion::coordinator::checkpoint::Checkpoint;
use spion::runtime::{ArtifactSet, Manifest, Runtime};

#[test]
fn all_shipped_configs_load() {
    let dir = std::path::Path::new("configs");
    let mut n = 0;
    for entry in std::fs::read_dir(dir).expect("configs/ exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let exp = load_experiment(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        assert!(exp.train.steps > 0);
        assert!(exp.sparsity.pattern.alpha > 0.0 && exp.sparsity.pattern.alpha < 1.0);
        n += 1;
    }
    assert!(n >= 4, "expected ≥4 shipped configs, found {n}");
}

#[test]
fn unknown_preset_is_rejected() {
    let err = spion::config::types::experiment_from_toml("preset = \"nonexistent\"").unwrap_err();
    assert!(err.contains("nonexistent"), "{err}");
}

#[test]
fn missing_artifacts_give_actionable_error() {
    let err = ArtifactSet::open("artifacts", "no-such-preset").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "hint missing: {msg}");
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("spion_corrupt_manifest");
    std::fs::create_dir_all(dir.join("tiny")).unwrap();
    std::fs::write(dir.join("tiny/manifest.json"), "{\"preset\": \"tiny\"").unwrap();
    let err = ArtifactSet::open(dir.to_str().unwrap(), "tiny").unwrap_err();
    assert!(format!("{err:#}").contains("manifest"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_semantic_validation() {
    // Structurally valid JSON but missing required keys.
    assert!(Manifest::parse("{\"preset\": \"x\"}").is_err());
    // params entry without shape.
    let bad = r#"{"preset":"x","task":"t","seq_len":8,"d_model":4,"heads":1,
        "layers":1,"ffn_dim":8,"vocab":4,"classes":2,"batch":1,
        "pattern_block":4,"lb":2,"params":[{"name":"embed"}]}"#;
    assert!(Manifest::parse(bad).is_err());
}

#[test]
fn truncated_checkpoint_is_rejected() {
    let path = std::env::temp_dir().join("spion_truncated.ckpt");
    // Valid magic, then garbage/truncation.
    std::fs::write(&path, b"SPIONCK1\x04\x00\x00\x00ti").unwrap();
    assert!(Checkpoint::load(path.to_str().unwrap()).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn runtime_load_rejects_invalid_hlo() {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let path = std::env::temp_dir().join("spion_bad.hlo.txt");
    std::fs::write(&path, "this is not HLO text").unwrap();
    assert!(rt.load(path.to_str().unwrap()).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn wrong_input_arity_fails_cleanly() {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let artifacts = ArtifactSet::open("artifacts", "tiny").unwrap();
    let exe = rt.load(&artifacts.path("dense_fwd")).unwrap();
    // dense_fwd expects params + x; give it a single scalar.
    let result = exe.run(&[xla::Literal::scalar(1.0f32)]);
    assert!(result.is_err(), "arity mismatch must error, not UB");
}
