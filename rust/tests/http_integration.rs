//! HTTP front-door integration: raw `TcpStream` exchanges against a live
//! [`HttpServer`] (no HTTP client library anywhere), plus an end-to-end
//! SIGTERM drain through the shipped binary.
//!
//! Covers the acceptance gates of the front-door PR: socket inference is
//! bit-identical to in-process submission, keep-alive pipelining works on
//! one connection, protocol limits answer with the right status codes,
//! overload sheds strictly lowest-class-first (witnessed through the
//! per-class /metrics counters), and a SIGTERM mid-flood drains with the
//! conservation line intact.

use spion::model::{Encoder, ModelParams};
use spion::obs::prom::Sources;
use spion::serve::http::{api_router, HttpConfig, HttpServer};
use spion::serve::{Class, Engine, ServeConfig, ServeError};
use spion::util::json::Json;
use spion::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Mirror of the manifest layout at an arbitrary small shape (same
/// builder as tests/serve_integration.rs).
fn random_params_shaped(
    rng: &mut Rng,
    layers: usize,
    vocab: usize,
    l: usize,
    d: usize,
    ffn: usize,
    classes: usize,
) -> ModelParams {
    let mut flat: Vec<(Vec<usize>, Vec<f32>)> = Vec::new();
    let mut mat = |r: usize, c: usize, rng: &mut Rng| {
        let mut data = vec![0.0f32; r * c];
        rng.fill_normal(&mut data, 0.3);
        (vec![r, c], data)
    };
    flat.push(mat(vocab, d, rng));
    flat.push(mat(l, d, rng));
    for _ in 0..layers {
        flat.push((vec![d], vec![1.0; d]));
        flat.push((vec![d], vec![0.0; d]));
        for _ in 0..4 {
            flat.push(mat(d, d, rng));
        }
        flat.push((vec![d], vec![1.0; d]));
        flat.push((vec![d], vec![0.0; d]));
        flat.push(mat(d, ffn, rng));
        flat.push((vec![ffn], vec![0.0; ffn]));
        flat.push(mat(ffn, d, rng));
        flat.push((vec![d], vec![0.0; d]));
    }
    flat.push(mat(d, classes, rng));
    flat.push((vec![classes], vec![0.0; classes]));
    ModelParams::from_flat(&flat, layers).unwrap()
}

/// Fast model (L = 16) for request-path tests.
fn small_encoder(rng: &mut Rng) -> Encoder {
    Encoder::new(random_params_shaped(rng, 2, 12, 16, 8, 32, 4), 2)
}

fn small_toks() -> Vec<i32> {
    (0..16).map(|i| (i % 12) as i32).collect()
}

/// Slow model (L = 128): one dense forward is orders of magnitude longer
/// than a submission, so overload scenarios are controllable.
fn big_encoder(rng: &mut Rng) -> Encoder {
    Encoder::new(random_params_shaped(rng, 2, 20, 128, 32, 64, 4), 2)
}

fn big_toks(rng: &mut Rng) -> Vec<i32> {
    (0..128).map(|_| rng.below(20) as i32).collect()
}

fn start_server(engine: &Arc<Engine>, cfg: &HttpConfig) -> HttpServer {
    let sources = Sources {
        server: Some(engine.stats().clone()),
        ops: Some(engine.op_tally()),
        health: Some(engine.health()),
    };
    let router = api_router(engine.clone(), sources, cfg.class_share);
    HttpServer::start("127.0.0.1:0", cfg, router).expect("bind ephemeral front door")
}

fn connect(addr: SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let s = TcpStream::connect(addr).expect("connect front door");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
    let r = BufReader::new(s.try_clone().expect("clone stream"));
    (s, r)
}

fn write_infer(s: &mut TcpStream, body: &str) {
    write!(
        s,
        "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("write request");
}

/// Read exactly one response off the stream: status, lowercased headers,
/// Content-Length-delimited body.
fn read_response(r: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut line = String::new();
    r.read_line(&mut line).expect("status line");
    assert!(line.starts_with("HTTP/1.1 "), "status line: {line:?}");
    let status: u16 =
        line.split_whitespace().nth(1).expect("status code").parse().expect("numeric status");
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).expect("header line");
        let t = h.trim_end().to_string();
        if t.is_empty() {
            break;
        }
        let (k, v) = t.split_once(':').expect("header colon");
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse().expect("content-length"))
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).expect("body");
    (status, headers, body)
}

/// One-shot GET over its own connection.
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let (mut s, mut r) = connect(addr);
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let (status, _, body) = read_response(&mut r);
    (status, String::from_utf8(body).expect("utf-8 body"))
}

fn tokens_json(toks: &[i32]) -> String {
    let items: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
    format!("[{}]", items.join(","))
}

/// Pull one sample value out of a Prometheus exposition.
fn metric_value(text: &str, line_prefix: &str) -> f64 {
    text.lines()
        .find(|l| l.starts_with(line_prefix))
        .unwrap_or_else(|| panic!("metric {line_prefix} missing from exposition"))
        .rsplit_once(' ')
        .expect("sample shape")
        .1
        .parse()
        .expect("numeric sample")
}

#[test]
fn socket_infer_is_bit_identical_to_in_process() {
    let mut rng = Rng::new(31);
    let engine = Arc::new(
        Engine::start(
            small_encoder(&mut rng),
            ServeConfig { queue_depth: 32, max_batch: 1, workers: 1, ..Default::default() },
        )
        .unwrap(),
    );
    let srv = start_server(&engine, &HttpConfig::default());
    let toks = small_toks();
    let expect = engine.try_submit(toks.clone()).unwrap().wait().unwrap();

    let (mut s, mut r) = connect(srv.addr());
    write_infer(&mut s, &format!("{{\"tokens\": {}}}", tokens_json(&toks)));
    let (status, _, body) = read_response(&mut r);
    assert_eq!(status, 200, "infer body: {}", String::from_utf8_lossy(&body));
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).expect("response is json");
    // The JSON float round-trip is exact: f32 → f64 is exact, and the
    // emitter prints shortest-roundtrip f64 — so logits compare by bits.
    let logits: Vec<f32> = v
        .get("logits")
        .and_then(|l| l.as_arr())
        .expect("logits array")
        .iter()
        .map(|x| x.as_f64().expect("numeric logit") as f32)
        .collect();
    assert_eq!(logits.len(), expect.logits.len());
    for (a, b) in logits.iter().zip(&expect.logits) {
        assert_eq!(a.to_bits(), b.to_bits(), "socket logits diverge from in-process");
    }
    assert_eq!(
        v.get("prediction").and_then(|p| p.as_f64()).expect("prediction") as usize,
        expect.class
    );
    assert_eq!(v.get("class").and_then(|c| c.as_str()), Some("interactive"));
    assert!(v.get("queue_us").and_then(|x| x.as_f64()).is_some(), "queue timing missing");
    assert!(v.get("exec_us").and_then(|x| x.as_f64()).is_some(), "exec timing missing");

    srv.stop();
    engine.shutdown();
}

#[test]
fn keep_alive_pipelines_three_requests_on_one_connection() {
    let mut rng = Rng::new(32);
    let engine = Arc::new(
        Engine::start(
            small_encoder(&mut rng),
            ServeConfig { queue_depth: 32, max_batch: 4, workers: 1, ..Default::default() },
        )
        .unwrap(),
    );
    let srv = start_server(&engine, &HttpConfig::default());
    let (mut s, mut r) = connect(srv.addr());
    // True pipelining: all three requests hit the wire before the first
    // response is read — the parser must carry leftover buffered bytes
    // across requests.
    for _ in 0..3 {
        write_infer(&mut s, &format!("{{\"tokens\": {}}}", tokens_json(&small_toks())));
    }
    let mut ids = Vec::new();
    for i in 0..3 {
        let (status, headers, body) = read_response(&mut r);
        assert_eq!(status, 200, "pipelined request {i}");
        let conn = headers.iter().find(|(k, _)| k == "connection").map(|(_, v)| v.as_str());
        assert_eq!(conn, Some("keep-alive"), "request {i} must keep the connection");
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        ids.push(v.get("id").and_then(|x| x.as_f64()).expect("id") as u64);
    }
    ids.dedup();
    assert_eq!(ids.len(), 3, "each pipelined request got its own ticket");
    assert_eq!(engine.stats().served.load(std::sync::atomic::Ordering::Relaxed), 3);
    srv.stop();
    engine.shutdown();
}

#[test]
fn oversized_body_gets_413_and_closes() {
    let mut rng = Rng::new(33);
    let engine = Arc::new(Engine::start(small_encoder(&mut rng), ServeConfig::default()).unwrap());
    let cfg = HttpConfig { max_body_bytes: 64, ..Default::default() };
    let srv = start_server(&engine, &cfg);
    let (mut s, mut r) = connect(srv.addr());
    // Declaring a body over the cap is rejected from the header alone —
    // the payload never needs to be read.
    let huge = "x".repeat(1024);
    write_infer(&mut s, &huge);
    let (status, headers, body) = read_response(&mut r);
    assert_eq!(status, 413, "body: {}", String::from_utf8_lossy(&body));
    let conn = headers.iter().find(|(k, _)| k == "connection").map(|(_, v)| v.as_str());
    assert_eq!(conn, Some("close"), "framing is untrusted after a protocol error");
    // The server closes without reading the oversized payload, which may
    // surface client-side as a clean EOF or a reset — both prove the close.
    let mut rest = Vec::new();
    match r.read_to_end(&mut rest) {
        Ok(_) => assert!(rest.is_empty(), "no bytes follow the 413"),
        Err(e) => assert_eq!(e.kind(), std::io::ErrorKind::ConnectionReset, "unexpected: {e}"),
    }
    srv.stop();
    engine.shutdown();
}

#[test]
fn malformed_requests_get_typed_400s() {
    let mut rng = Rng::new(34);
    let engine = Arc::new(Engine::start(small_encoder(&mut rng), ServeConfig::default()).unwrap());
    let srv = start_server(&engine, &HttpConfig::default());
    // (body, expected reason fragment)
    let cases = [
        ("{nope", "invalid json"),
        ("{\"class\": \"batch\"}", "missing required field"),
        ("{\"tokens\": [1], \"class\": \"urgent\"}", "unknown class"),
    ];
    for (bad, needle) in cases {
        let (mut s, mut r) = connect(srv.addr());
        write_infer(&mut s, bad);
        let (status, _, body) = read_response(&mut r);
        assert_eq!(status, 400, "case {bad:?}");
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).expect("error body is json");
        assert_eq!(v.get("error").and_then(|e| e.as_str()), Some("bad_request"));
        let reason = v.get("reason").and_then(|x| x.as_str()).expect("typed reason");
        assert!(reason.contains(needle), "case {bad:?}: reason {reason:?}");
    }
    // Unknown path and wrong method get the right negatives too.
    let (status, _) = http_get(srv.addr(), "/nope");
    assert_eq!(status, 404);
    let (status, _) = http_get(srv.addr(), "/v1/infer");
    assert_eq!(status, 405, "GET on a POST route");
    srv.stop();
    engine.shutdown();
}

#[test]
fn overload_sheds_best_effort_strictly_before_interactive() {
    let mut rng = Rng::new(35);
    let engine = Arc::new(
        Engine::start(
            big_encoder(&mut rng),
            ServeConfig { queue_depth: 4, max_batch: 1, workers: 1, ..Default::default() },
        )
        .unwrap(),
    );
    let srv = start_server(&engine, &HttpConfig::default());

    // Occupy the single worker and wait for the pop, so the queue below
    // is stable while we fill it (one dense L=128 forward ≫ setup cost).
    let busy = engine.try_submit(big_toks(&mut rng)).unwrap();
    while engine.queue_len() > 0 {
        std::thread::yield_now();
    }
    // Fill the queue with best-effort, then flood interactive: every
    // interactive arrival must displace a queued best-effort entry.
    let be: Vec<_> = (0..4)
        .map(|_| engine.try_submit_classed(big_toks(&mut rng), Class::BestEffort, None).unwrap())
        .collect();
    let hi: Vec<_> = (0..4)
        .map(|_| engine.try_submit_classed(big_toks(&mut rng), Class::Interactive, None).unwrap())
        .collect();
    let mut preempted = 0;
    for t in &be {
        match t.wait() {
            Err(ServeError::Preempted) => preempted += 1,
            other => panic!("best-effort must be preempted, got {other:?}"),
        }
    }
    assert_eq!(preempted, 4, "every queued best-effort displaced");
    assert!(busy.wait().is_ok());
    for t in &hi {
        assert!(t.wait().is_ok(), "interactive is never shed while lower classes queue");
    }

    // The shed order is witnessed over the socket: per-class counters in
    // the Prometheus exposition.
    let (status, metrics) = http_get(srv.addr(), "/metrics");
    assert_eq!(status, 200);
    let be_pre = metric_value(&metrics, "spion_serve_class_preempted_total{class=\"best_effort\"}");
    let hi_pre = metric_value(&metrics, "spion_serve_class_preempted_total{class=\"interactive\"}");
    assert_eq!(be_pre, 4.0, "best-effort preemptions visible in /metrics");
    assert_eq!(hi_pre, 0.0, "interactive is never preempted");
    let hi_served =
        metric_value(&metrics, "spion_serve_class_served_total{class=\"interactive\"}");
    assert_eq!(hi_served, 5.0, "busy + 4 displacing requests served");
    // Per-class request-latency summary families render.
    assert!(
        metrics.contains("spion_http_request_seconds{class=\"interactive\",quantile=\"0.5\"}"),
        "per-class latency summary missing"
    );

    // Exactly-once conservation across the whole flood.
    use std::sync::atomic::Ordering::Relaxed;
    let stats = engine.stats();
    let admitted = stats.admitted.load(Relaxed);
    let resolved = stats.served.load(Relaxed) + stats.preempted.load(Relaxed);
    assert_eq!(admitted, resolved, "admitted = served + preempted");
    srv.stop();
    engine.shutdown();
}

#[test]
fn class_share_gate_turns_batch_away_at_the_door() {
    let mut rng = Rng::new(36);
    let engine = Arc::new(
        Engine::start(
            big_encoder(&mut rng),
            ServeConfig { queue_depth: 8, max_batch: 1, workers: 1, ..Default::default() },
        )
        .unwrap(),
    );
    // batch may hold at most floor(0.25 × 8) = 2 admission slots.
    let cfg = HttpConfig { class_share: [1.0, 0.25, 0.25], ..Default::default() };
    let srv = start_server(&engine, &cfg);
    let busy = engine.try_submit(big_toks(&mut rng)).unwrap();
    while engine.queue_len() > 0 {
        std::thread::yield_now();
    }
    let queued: Vec<_> = (0..2)
        .map(|_| engine.try_submit_classed(big_toks(&mut rng), Class::Batch, None).unwrap())
        .collect();
    // The third batch request arrives over the socket and must be turned
    // away by the share gate even though the queue has free depth.
    let (mut s, mut r) = connect(srv.addr());
    write_infer(
        &mut s,
        &format!("{{\"tokens\": {}, \"class\": \"batch\"}}", tokens_json(&big_toks(&mut rng))),
    );
    let (status, headers, body) = read_response(&mut r);
    assert_eq!(status, 503, "body: {}", String::from_utf8_lossy(&body));
    let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(v.get("error").and_then(|e| e.as_str()), Some("class_share_exceeded"));
    assert!(
        headers.iter().any(|(k, v)| k == "retry-after" && v == "1"),
        "shed responses carry Retry-After"
    );
    assert!(busy.wait().is_ok());
    for t in queued {
        assert!(t.wait().is_ok());
    }
    srv.stop();
    engine.shutdown();
}

/// End-to-end through the shipped binary: SIGTERM mid-flood drains
/// gracefully and prints the conservation line.
#[test]
fn sigterm_mid_flood_drains_with_conservation() {
    let dir = std::env::temp_dir().join(format!("spion-http-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.bin");
    let bin = env!("CARGO_BIN_EXE_spion");
    let train = std::process::Command::new(bin)
        .args(["train", "--preset", "tiny", "--backend", "native", "--steps", "2"])
        .arg("--checkpoint-out")
        .arg(&ck)
        .output()
        .expect("spawn train");
    assert!(train.status.success(), "train failed:\n{}", String::from_utf8_lossy(&train.stderr));

    let mut serve = std::process::Command::new(bin)
        .args(["serve", "--preset", "tiny", "--checkpoint"])
        .arg(&ck)
        .args([
            "--requests",
            "0",
            "--http-addr",
            "127.0.0.1:0",
            "--hold-ms",
            "60000",
        ])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn serve");
    let stdout = serve.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut addr: Option<SocketAddr> = None;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap_or(0) > 0 {
        if let Some(rest) = line.trim().strip_prefix("http listening on http://") {
            addr = Some(rest.parse().expect("socket addr in banner"));
        }
        if line.starts_with("holding for") {
            break;
        }
        line.clear();
    }
    let addr = addr.expect("serve never printed the http banner");

    // tiny preset: L = 128, vocab 20.
    let toks: Vec<i32> = (0..128).map(|i| (i % 20) as i32).collect();
    let body = format!("{{\"tokens\": {}}}", tokens_json(&toks));
    // A few synchronous requests guarantee admitted > 0 before the drain.
    for _ in 0..2 {
        let (mut s, mut r) = connect(addr);
        write_infer(&mut s, &body);
        let (status, _, _) = read_response(&mut r);
        assert_eq!(status, 200, "warm-up infer failed");
    }
    // Flood from background threads with mixed classes while SIGTERM
    // lands; responses and connection errors are both acceptable — the
    // conservation line is the oracle.
    let flood: Vec<_> = (0..4)
        .map(|i| {
            let class = if i % 2 == 0 { "interactive" } else { "best_effort" };
            let body =
                format!("{{\"tokens\": {}, \"class\": \"{class}\"}}", tokens_json(&toks));
            std::thread::spawn(move || {
                for _ in 0..8 {
                    let Ok(mut s) = TcpStream::connect(addr) else { return };
                    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                    if write!(
                        s,
                        "POST /v1/infer HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
                        body.len()
                    )
                    .is_err()
                    {
                        return;
                    }
                    let mut sink = Vec::new();
                    let _ = s.read_to_end(&mut sink);
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(100));
    let kill = std::process::Command::new("kill")
        .args(["-TERM", &serve.id().to_string()])
        .status()
        .expect("spawn kill");
    assert!(kill.success(), "kill -TERM failed");
    for h in flood {
        let _ = h.join();
    }

    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    let status = serve.wait().expect("wait serve");
    assert!(status.success(), "serve exited non-zero; tail:\n{rest}");
    assert!(rest.contains("SIGTERM received"), "drain path not taken; tail:\n{rest}");
    let drain = rest
        .lines()
        .find(|l| l.starts_with("drain complete:"))
        .unwrap_or_else(|| panic!("conservation line missing; tail:\n{rest}"));
    // "drain complete: R/A admitted tickets resolved (...)"
    let frac = drain
        .strip_prefix("drain complete: ")
        .and_then(|s| s.split_whitespace().next())
        .expect("resolved/admitted fraction");
    let (resolved, admitted) = frac.split_once('/').expect("R/A shape");
    let resolved: u64 = resolved.parse().unwrap();
    let admitted: u64 = admitted.parse().unwrap();
    assert!(admitted >= 2, "warm-up requests were admitted: {drain}");
    assert_eq!(resolved, admitted, "every admitted ticket resolved exactly once: {drain}");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_loris_gets_408_and_frees_the_only_conn_worker() {
    let mut rng = Rng::new(77);
    let engine = Arc::new(
        Engine::start(
            small_encoder(&mut rng),
            ServeConfig { queue_depth: 8, max_batch: 1, workers: 1, ..Default::default() },
        )
        .unwrap(),
    );
    // One connection worker and a short idle deadline: if the trickled
    // request pinned the worker, the follow-up request below would hang.
    let cfg = HttpConfig { conn_workers: 1, idle_timeout_ms: 600, ..Default::default() };
    let srv = start_server(&engine, &cfg);

    let (s, mut r) = connect(srv.addr());
    // Trickle one header byte per 200 ms from a side thread — each sliced
    // read on the server succeeds, so only the between-reads deadline
    // check can fire. The main thread blocks reading the response so the
    // 408 is consumed before any post-close write can trigger a reset.
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let trickler = {
        let done = done.clone();
        let mut s = s.try_clone().expect("clone trickle stream");
        std::thread::spawn(move || {
            for &b in b"GET /metrics HTTP/1.1\r\nHost: t".iter() {
                if done.load(std::sync::atomic::Ordering::Relaxed) || s.write_all(&[b]).is_err() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        })
    };
    let (status, headers, body) = read_response(&mut r);
    done.store(true, std::sync::atomic::Ordering::Relaxed);
    trickler.join().expect("trickler thread");
    assert_eq!(status, 408, "body: {}", String::from_utf8_lossy(&body));
    let conn = headers.iter().find(|(k, _)| k == "connection").map(|(_, v)| v.as_str());
    assert_eq!(conn, Some("close"), "a timed-out request closes the connection");

    // The lone worker must be reclaimed: a fresh connection gets a full
    // /metrics exposition instead of queueing behind the loris.
    let (status, text) = http_get(srv.addr(), "/metrics");
    assert_eq!(status, 200, "worker not reclaimed after the 408");
    // No request ever completed admission, so the serve counters are
    // all intact — the loris burned only the idle deadline.
    assert_eq!(metric_value(&text, "spion_serve_served_total"), 0.0);

    srv.stop();
    engine.shutdown();
}
