//! Serial ↔ parallel parity for the whole block-sparse pipeline.
//!
//! The `exec` determinism contract (DESIGN.md §exec): every kernel's
//! parallel form writes disjoint outputs with serial per-element order, so
//! SDDMM / sparse softmax / SpMM / transposed SpMM / backward must match
//! the serial engine **bit for bit** at every worker count in deterministic
//! mode — and within 1e-5 otherwise (the non-deterministic mode only
//! re-chunks reductions; the kernels themselves stay exact, so the loose
//! tolerance is an upper bound, not an expectation).
//!
//! Patterns under test span the full policy zoo: SPION-C/-F/-CF (the paper's
//! variants), BigBird, and the Reformer/LSH baseline — plus worker counts
//! {1, 2, 4} including the `workers = 1` no-pool path, which runs the
//! literal serial loops.

use spion::attention::{
    dense_mha, dense_mha_with, sparse_attention_train, sparse_attention_train_with, sparse_mha,
    sparse_mha_with, MhaWorkspace, TrainWorkspace,
};
use spion::exec::{Exec, ExecConfig};
use spion::pattern::bigbird::bigbird;
use spion::pattern::lsh::lsh_pattern;
use spion::pattern::spion::{generate_pattern, synth_attention_scores, PatternConfig};
use spion::pattern::{BlockMask, SpionVariant};
use spion::sparse::backward::{spmm_t, spmm_t_with};
use spion::sparse::bcsr::Bcsr;
use spion::sparse::sddmm::{sddmm, sddmm_with};
use spion::sparse::softmax::{sparse_softmax, sparse_softmax_with};
use spion::sparse::spmm::{spmm, spmm_with};
use spion::tensor::Mat;
use spion::util::quickcheck::{assert_allclose, QuickCheck};
use spion::util::rng::Rng;

/// Build the executed-against contexts: serial plus pooled variants.
fn contexts(deterministic: bool) -> Vec<Exec> {
    [1usize, 2, 4]
        .into_iter()
        .map(|workers| {
            Exec::new(ExecConfig { workers, chunk_blocks: 0, deterministic, ..Default::default() })
        })
        .collect()
}

/// A pattern from every policy the engine supports, at block size `block`.
fn pattern_zoo(rng: &mut Rng, l: usize, block: usize) -> Vec<(String, BlockMask)> {
    let scores = synth_attention_scores(l, 0.8, 0.4, &[l / 3], 0.05, rng);
    let lb = l / block;
    let mut zoo = Vec::new();
    for variant in [SpionVariant::C, SpionVariant::F, SpionVariant::CF] {
        let cfg = PatternConfig { variant, block, filter: 5, alpha: 0.5 + 0.45 * rng.f64() };
        zoo.push((variant.name().to_string(), generate_pattern(&scores, &cfg)));
    }
    zoo.push(("BigBird".into(), bigbird(lb, block, &Default::default(), rng)));
    zoo.push(("Reformer".into(), lsh_pattern(&scores, block, &Default::default(), rng)));
    zoo
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

#[test]
fn forward_kernels_bit_identical_across_workers() {
    QuickCheck::new().cases(12).run("fwd kernel parity", |rng| {
        let block = [4usize, 8][rng.below(2)];
        let lb = 3 + rng.below(5);
        let l = lb * block;
        let d = 1 + rng.below(12);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 1.0, rng);
        let k = Mat::random_normal(l, d, 1.0, rng);
        let v = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            // Serial reference through the legacy entry points.
            let mut s_ref = Bcsr::from_mask(&mask);
            sddmm(&q, &k, &mut s_ref, scale);
            let logits_ref = s_ref.clone();
            sparse_softmax(&mut s_ref, 1.0, true);
            let mut out_ref = Mat::zeros(l, d);
            spmm(&s_ref, &v, &mut out_ref);
            let mut t_ref = Mat::zeros(l, d);
            spmm_t(&s_ref, &v, &mut t_ref);

            for exec in contexts(true) {
                let tag = format!("{name} w={}", exec.workers());
                let mut s = Bcsr::from_mask(&mask);
                sddmm_with(&exec, &q, &k, &mut s, scale);
                assert_bits_eq(&s.values, &logits_ref.values, &format!("sddmm {tag}"));
                sparse_softmax_with(&exec, &mut s, 1.0, true);
                assert_bits_eq(&s.values, &s_ref.values, &format!("softmax {tag}"));
                let mut out = Mat::zeros(l, d);
                spmm_with(&exec, &s, &v, &mut out);
                assert_bits_eq(&out.data, &out_ref.data, &format!("spmm {tag}"));
                let mut t = Mat::zeros(l, d);
                spmm_t_with(&exec, &s, &v, &mut t);
                assert_bits_eq(&t.data, &t_ref.data, &format!("spmm_t {tag}"));
            }
        }
        Ok(())
    });
}

#[test]
fn backward_bit_identical_across_workers() {
    QuickCheck::new().cases(10).run("bwd parity", |rng| {
        let block = [4usize, 8][rng.below(2)];
        let lb = 2 + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(8);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.8, rng);
        let k = Mat::random_normal(l, d, 0.8, rng);
        let v = Mat::random_normal(l, d, 0.8, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let mut ws_ref = TrainWorkspace::new(&mask, d);
            sparse_attention_train(&q, &k, &v, scale, &cot, &mut ws_ref);

            for exec in contexts(true) {
                let tag = format!("{name} w={}", exec.workers());
                let mut ws = TrainWorkspace::new(&mask, d);
                sparse_attention_train_with(&exec, &q, &k, &v, scale, &cot, &mut ws);
                assert_bits_eq(&ws.fwd.ctx.data, &ws_ref.fwd.ctx.data, &format!("ctx {tag}"));
                assert_bits_eq(&ws.dq.data, &ws_ref.dq.data, &format!("dQ {tag}"));
                assert_bits_eq(&ws.dk.data, &ws_ref.dk.data, &format!("dK {tag}"));
                assert_bits_eq(&ws.dv.data, &ws_ref.dv.data, &format!("dV {tag}"));
            }
        }
        Ok(())
    });
}

#[test]
fn mha_level_parity_dense_and_sparse() {
    QuickCheck::new().cases(8).run("mha parity", |rng| {
        let heads = [1usize, 2, 4][rng.below(3)];
        let block = 4;
        let lb = 3 + rng.below(4);
        let l = lb * block;
        let d = heads * (2 + rng.below(6));
        let q = Mat::random_normal(l, d, 1.0, rng);
        let k = Mat::random_normal(l, d, 1.0, rng);
        let v = Mat::random_normal(l, d, 1.0, rng);

        // Dense MHA: context and head-averaged scores.
        let (out_ref, scores_ref) = dense_mha(&q, &k, &v, heads);
        for exec in contexts(true) {
            let (out, scores) = dense_mha_with(&exec, &q, &k, &v, heads);
            assert_bits_eq(&out.data, &out_ref.data, &format!("dense ctx w={}", exec.workers()));
            assert_bits_eq(
                &scores.data,
                &scores_ref.data,
                &format!("dense A^s w={}", exec.workers()),
            );
        }

        // Sparse MHA across the pattern zoo (shared per-layer mask).
        for (name, mask) in pattern_zoo(rng, l, block) {
            let mut ws_ref = MhaWorkspace::new(&mask, heads, d);
            let sparse_ref = sparse_mha(&q, &k, &v, &mut ws_ref).clone();
            for exec in contexts(true) {
                let mut ws = MhaWorkspace::new(&mask, heads, d);
                let sparse = sparse_mha_with(&exec, &q, &k, &v, &mut ws);
                assert_bits_eq(
                    &sparse.data,
                    &sparse_ref.data,
                    &format!("sparse mha {name} w={}", exec.workers()),
                );
            }
        }
        Ok(())
    });
}

#[test]
fn non_deterministic_mode_stays_within_tolerance() {
    // Non-deterministic mode only changes reduction chunking; the kernels
    // keep disjoint writes, so outputs still land within (and in practice
    // at) the documented 1e-5 envelope of the serial engine.
    QuickCheck::new().cases(8).run("non-det tolerance", |rng| {
        let block = 4;
        let lb = 3 + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(8);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 1.0, rng);
        let k = Mat::random_normal(l, d, 1.0, rng);
        let v = Mat::random_normal(l, d, 1.0, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let mut ws_ref = TrainWorkspace::new(&mask, d);
            sparse_attention_train(&q, &k, &v, scale, &cot, &mut ws_ref);
            for exec in contexts(false) {
                let mut ws = TrainWorkspace::new(&mask, d);
                sparse_attention_train_with(&exec, &q, &k, &v, scale, &cot, &mut ws);
                for (what, got, want) in [
                    ("ctx", &ws.fwd.ctx, &ws_ref.fwd.ctx),
                    ("dq", &ws.dq, &ws_ref.dq),
                    ("dk", &ws.dk, &ws_ref.dk),
                    ("dv", &ws.dv, &ws_ref.dv),
                ] {
                    assert_allclose(&got.data, &want.data, 1e-5, 1e-6).unwrap_or_else(|e| {
                        panic!("{name} {what} w={}: {e}", exec.workers())
                    });
                }
            }
        }
        Ok(())
    });
}

#[test]
fn op_tally_aggregates_identically_across_workers() {
    // The per-worker tallies must sum to the same totals no matter how the
    // chunks land — op accounting is scheduling-independent.
    let mut rng = Rng::new(99);
    let block = 4;
    let l = 32;
    let d = 8;
    let q = Mat::random_normal(l, d, 1.0, &mut rng);
    let k = Mat::random_normal(l, d, 1.0, &mut rng);
    let (_, mask) = pattern_zoo(&mut rng, l, block).remove(2); // SPION-CF
    let mut totals = Vec::new();
    for exec in contexts(true) {
        exec.reset_ops();
        let mut s = Bcsr::from_mask(&mask);
        sddmm_with(&exec, &q, &k, &mut s, 1.0);
        sparse_softmax_with(&exec, &mut s, 1.0, true);
        totals.push(exec.op_counter());
    }
    assert!(totals[0].flops() > 0, "tally recorded work");
    for t in &totals[1..] {
        assert_eq!(t.mul_add, totals[0].mul_add);
        assert_eq!(t.exp, totals[0].exp);
        assert_eq!(t.cmp, totals[0].cmp);
    }
}
