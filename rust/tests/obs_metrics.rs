//! Observability integration tests: span registry semantics (enabled /
//! disabled), the chrome-trace ring, the Prometheus /metrics endpoint over
//! raw TCP against a live serving engine, and an end-to-end spawn of the
//! `spion` binary (train a checkpoint, serve it with `--metrics-addr` +
//! `--trace-out`, scrape the ephemeral port).
//!
//! These tests mutate process-global obs state (the static span registry,
//! the ENABLED flag, the trace ring), so everything that touches globals
//! serializes on one lock — and lives in this integration binary, a
//! separate process from the unit-test binary, so lib tests never race it.

use spion::config::ModelConfig;
use spion::model::{Encoder, ModelParams};
use spion::obs::{self, SpanId};
use spion::pattern::BlockMask;
use spion::serve::{Engine, ServeConfig};
use spion::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

static GLOBAL: Mutex<()> = Mutex::new(());

/// Poison-tolerant lock: one failing test must not cascade into every
/// later test dying on `PoisonError`.
fn lock_globals() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Small sparse model through the public surface (L=32, D=32, 2 layers,
/// diagonal block mask) — big enough to exercise every serve span.
fn encoder() -> Encoder {
    let model = ModelConfig {
        preset: "obs-test".into(),
        seq_len: 32,
        d_model: 32,
        heads: 2,
        layers: 2,
        ffn_dim: 64,
        vocab: 20,
        classes: 4,
        batch: 1,
    };
    let params = ModelParams::init_random(&model, 9);
    let mut m = BlockMask::empty(8, 4);
    m.set_diagonal();
    Encoder::new(params, 2).with_masks(vec![m.clone(), m]).unwrap()
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect to metrics endpoint");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).expect("read response");
    buf
}

#[test]
fn spans_record_into_the_registry() {
    let _g = lock_globals();
    obs::set_enabled(true);
    let before = obs::snapshot(SpanId::Embed).count;
    {
        let _sp = obs::span(SpanId::Embed);
    }
    obs::record(SpanId::Embed, Duration::from_micros(5));
    let after = obs::snapshot(SpanId::Embed).count;
    assert_eq!(after, before + 2, "guard drop + explicit record each add one sample");
}

#[test]
fn disabled_spans_are_no_ops() {
    let _g = lock_globals();
    obs::set_enabled(false);
    let before = obs::snapshot(SpanId::Optimizer).count;
    for _ in 0..100 {
        let _sp = obs::span(SpanId::Optimizer);
    }
    obs::record(SpanId::Optimizer, Duration::from_micros(5));
    let after = obs::snapshot(SpanId::Optimizer).count;
    obs::set_enabled(true);
    assert_eq!(after, before, "disabled registry must record nothing");
}

#[test]
fn trace_ring_dumps_valid_chrome_json() {
    let _g = lock_globals();
    obs::set_enabled(true);
    obs::trace::enable(1024);
    {
        let _sp = obs::span(SpanId::TransitionStep);
        std::thread::sleep(Duration::from_millis(1));
    }
    let dump = obs::trace::dump_json();
    obs::trace::disable();
    assert!(dump.contains("transition_step"), "span name missing from trace");
    assert!(dump.contains("\"ph\":\"X\""), "complete-event phase missing");
    let j = Json::parse(&dump).expect("trace dump is valid JSON");
    let events = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    assert!(!events.is_empty(), "ring captured no events");
    let (captured, _) = obs::trace::stats();
    assert!(captured >= 1);
}

#[test]
fn metrics_endpoint_serves_prometheus_text() {
    let _g = lock_globals();
    obs::set_enabled(true);
    let engine = Engine::start(
        encoder(),
        ServeConfig { queue_depth: 32, max_batch: 4, workers: 1, ..Default::default() },
    )
    .unwrap();
    let srv = spion::serve::http::HttpServer::start(
        "127.0.0.1:0",
        &spion::serve::http::HttpConfig::default(),
        spion::serve::http::metrics_router(obs::prom::Sources {
            server: Some(engine.stats().clone()),
            ops: Some(engine.op_tally()),
            health: Some(engine.health()),
        }),
    )
    .unwrap();
    let addr = srv.addr();

    for i in 0..8 {
        let toks: Vec<i32> = (0..32).map(|t| ((t + i) % 20) as i32).collect();
        engine.submit(toks).unwrap().wait().unwrap();
    }

    let resp = http_get(addr, "/metrics");
    let (head, body) = resp.split_once("\r\n\r\n").expect("header/body split");
    assert!(head.starts_with("HTTP/1.1 200"), "bad status: {head}");
    assert!(head.contains("text/plain"), "bad content type: {head}");
    for family in [
        "spion_obs_enabled",
        "spion_span_seconds",
        "spion_span_duration_seconds_bucket",
        "spion_serve_served_total",
        "spion_request_latency_seconds",
        "spion_queue_wait_seconds",
        "spion_ops_total",
        "spion_trace_events_dropped_total",
        "spion_serve_failed_total",
        "spion_resil_worker_respawns_total",
        "spion_resil_deadline_shed_total",
        "spion_resil_resume_total",
        "spion_resil_checkpoint_write_seconds",
        "spion_serve_health",
    ] {
        assert!(body.contains(family), "family {family} missing from exposition");
    }
    // The workload ran through the engine, so the serve counters and the
    // request-latency summary must be non-empty.
    assert!(body.contains("spion_serve_served_total 8"), "served count wrong:\n{body}");
    assert!(
        body.lines().any(|l| {
            l.starts_with("spion_request_latency_seconds_count")
                && l.ends_with(" 8")
        }),
        "latency histogram not populated"
    );
    // Every sample line is `name{{labels}} value` with a finite value —
    // the "parseable" half of the acceptance gate.
    for line in body.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (_, val) = line.rsplit_once(' ').expect("sample line shape");
        let v: f64 = val.parse().unwrap_or_else(|_| panic!("non-numeric sample: {line}"));
        assert!(v.is_finite(), "non-finite sample: {line}");
    }

    let health = http_get(addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 200"));
    assert!(health.ends_with("ok\n"));
    let missing = http_get(addr, "/nope");
    assert!(missing.starts_with("HTTP/1.1 404"));

    // Shutdown flips the shared health cell to draining — /healthz and the
    // gauge follow, still HTTP 200 (orchestrators key off the body).
    engine.shutdown();
    let health = http_get(addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 200"));
    assert!(health.ends_with("draining\n"), "post-shutdown health: {health}");
    let resp = http_get(addr, "/metrics");
    assert!(
        resp.contains("spion_serve_health{state=\"draining\"} 2"),
        "health gauge did not follow drain"
    );
    srv.stop();
}

/// End-to-end through the shipped binary: train a tiny native checkpoint,
/// serve it with an ephemeral /metrics port and a trace dump, scrape the
/// endpoint during the `--hold-ms` window.
#[test]
fn serve_binary_exposes_metrics_and_trace() {
    let dir = std::env::temp_dir().join(format!("spion-obs-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ck = dir.join("ck.bin");
    let trace = dir.join("trace.json");

    let bin = env!("CARGO_BIN_EXE_spion");
    let train = std::process::Command::new(bin)
        .args([
            "train",
            "--preset",
            "tiny",
            "--backend",
            "native",
            "--steps",
            "2",
            "--checkpoint-out",
        ])
        .arg(&ck)
        .output()
        .expect("spawn train");
    assert!(
        train.status.success(),
        "train failed:\n{}",
        String::from_utf8_lossy(&train.stderr)
    );

    let mut serve = std::process::Command::new(bin)
        .args(["serve", "--preset", "tiny", "--checkpoint"])
        .arg(&ck)
        .args([
            "--requests",
            "16",
            "--concurrency",
            "2",
            "--metrics-addr",
            "127.0.0.1:0",
            "--hold-ms",
            "4000",
            "--trace-out",
        ])
        .arg(&trace)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn serve");

    let stdout = serve.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut addr: Option<SocketAddr> = None;
    let mut workload_done = false;
    let mut line = String::new();
    // The engine prints the ephemeral port right after binding, runs the
    // synthetic workload, prints the latency summary, then holds. Scrape
    // inside the hold window so the histograms are fully populated.
    while reader.read_line(&mut line).unwrap_or(0) > 0 {
        if let Some(rest) = line.trim().strip_prefix("metrics listening on http://") {
            let host = rest.strip_suffix("/metrics").unwrap_or(rest);
            addr = Some(host.parse().expect("socket addr in banner"));
        }
        if line.starts_with("holding for") {
            workload_done = true;
            break;
        }
        line.clear();
    }
    let addr = addr.expect("serve never printed the metrics banner");
    assert!(workload_done, "serve never reached the hold window");

    let resp = http_get(addr, "/metrics");
    assert!(resp.starts_with("HTTP/1.1 200"), "bad scrape: {resp}");
    for family in
        ["spion_span_seconds", "spion_serve_served_total", "spion_request_latency_seconds"]
    {
        assert!(resp.contains(family), "family {family} missing:\n{resp}");
    }
    assert!(
        !resp.contains("spion_serve_served_total 0\n"),
        "workload ran but served counter is zero"
    );

    // Drain the rest of stdout (the child blocks on a full pipe otherwise)
    // and wait for a clean exit.
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    let status = serve.wait().expect("wait serve");
    assert!(status.success(), "serve exited non-zero; tail:\n{rest}");

    let trace_json = std::fs::read_to_string(&trace).expect("trace file written");
    assert!(trace_json.contains("traceEvents"));
    Json::parse(&trace_json).expect("trace file is valid JSON");

    let _ = std::fs::remove_dir_all(&dir);
}
