//! Python ↔ Rust golden-vector parity.
//!
//! `make artifacts` dumps randomized cases through the python reference
//! (`python/compile/pattern_ref.py`, `kernels/ref.py`) into
//! `artifacts/golden/*.json`; these tests replay them through the rust
//! implementations and demand equality (exact for masks, allclose for
//! float intermediates). Skipped with a notice if artifacts are missing.

use spion::pattern::conv::{conv_diag, diagonal_filter};
use spion::pattern::flood::flood_fill_all;
use spion::pattern::pool::avg_pool;
use spion::pattern::spion::{generate_pattern, PatternConfig};
use spion::pattern::{BlockMask, SpionVariant};
use spion::sparse::bcsr::Bcsr;
use spion::sparse::sddmm::sddmm;
use spion::sparse::softmax::sparse_softmax;
use spion::sparse::spmm::spmm_alloc;
use spion::tensor::Mat;
use spion::util::json::Json;
use spion::util::quickcheck::assert_allclose;

fn load_golden(name: &str) -> Option<Json> {
    let path = format!("artifacts/golden/{name}");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => {
            eprintln!("SKIP: {path} missing — run `make artifacts`");
            return None;
        }
    };
    Some(Json::parse(&text).expect("golden json parses"))
}

fn f32s(j: &Json, key: &str) -> Vec<f32> {
    j.get(key).unwrap_or(&Json::Null).as_f32_vec().unwrap_or_else(|| panic!("{key} missing"))
}

#[test]
fn pattern_golden_parity() {
    let Some(golden) = load_golden("pattern_golden.json") else { return };
    let cases = golden.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 4);
    for (idx, case) in cases.iter().enumerate() {
        let l = case.get("l").unwrap().as_usize().unwrap();
        let block = case.get("block").unwrap().as_usize().unwrap();
        let filter = case.get("filter").unwrap().as_usize().unwrap();
        let alpha = case.get("alpha").unwrap().as_f64().unwrap();
        let variant = match case.get("variant").unwrap().as_str().unwrap() {
            "C" => SpionVariant::C,
            "F" => SpionVariant::F,
            "CF" => SpionVariant::CF,
            other => panic!("unknown variant {other}"),
        };
        let scores = Mat::from_vec(l, l, f32s(case, "scores"));

        // Stage parity: conv.
        let conv_expect = f32s(case, "conv_out");
        let conv_got = if variant == SpionVariant::F {
            scores.clone()
        } else {
            conv_diag(&scores, &diagonal_filter(filter))
        };
        assert_allclose(&conv_got.data, &conv_expect, 1e-3, 1e-5)
            .unwrap_or_else(|e| panic!("case {idx}: conv mismatch: {e}"));

        // Stage parity: pool.
        let pool_expect = f32s(case, "pool_out");
        let pool_got = avg_pool(&conv_got, block);
        assert_allclose(&pool_got.data, &pool_expect, 1e-3, 1e-5)
            .unwrap_or_else(|e| panic!("case {idx}: pool mismatch: {e}"));

        // Stage parity: flood fill over the PYTHON pool values with the
        // PYTHON threshold — identical comparisons on identical f32 inputs
        // ⇒ exact mask equality required.
        if let Some(fl_expect) = case.get("flood_from_pool").filter(|v| !matches!(v, Json::Null)) {
            let t = case.get("threshold").unwrap().as_f64().unwrap() as f32;
            let lb = l / block;
            let pool_py = Mat::from_vec(lb, lb, pool_expect.clone());
            let fl = flood_fill_all(&pool_py, t);
            let expect: Vec<f32> = fl_expect.as_f32_vec().unwrap();
            assert_eq!(fl.data, expect, "case {idx}: flood fill mask differs");
        }

        // End-to-end parity (exact mask match).
        let cfg = PatternConfig { variant, block, filter, alpha };
        let mask = generate_pattern(&scores, &cfg);
        let expect_bits: Vec<bool> =
            f32s(case, "mask").iter().map(|&v| v != 0.0).collect();
        assert_eq!(
            mask.bits, expect_bits,
            "case {idx} ({variant:?}, l={l}, block={block}): end-to-end mask differs"
        );
    }
}

#[test]
fn attention_engine_golden_parity() {
    let Some(golden) = load_golden("attention_golden.json") else { return };
    let cases = golden.get("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 3);
    for (idx, case) in cases.iter().enumerate() {
        let l = case.get("l").unwrap().as_usize().unwrap();
        let dh = case.get("dh").unwrap().as_usize().unwrap();
        let block = case.get("block").unwrap().as_usize().unwrap();
        let scale = case.get("scale").unwrap().as_f64().unwrap() as f32;
        let lb = l / block;
        let q = Mat::from_vec(l, dh, f32s(case, "q"));
        let k = Mat::from_vec(l, dh, f32s(case, "k"));
        let v = Mat::from_vec(l, dh, f32s(case, "v"));
        let bits: Vec<bool> = f32s(case, "block_mask").iter().map(|&x| x != 0.0).collect();
        let mask = BlockMask { lb, block, bits };

        // Engine pipeline: SDDMM → sparse softmax → SpMM.
        let mut s = Bcsr::from_mask(&mask);
        sddmm(&q, &k, &mut s, scale);
        sparse_softmax(&mut s, 1.0, true);

        // S^s parity at stored positions (jnp computed the dense-equivalent
        // closed form).
        let s_expect = Mat::from_vec(l, l, f32s(case, "s_sparse"));
        let s_got = s.to_dense();
        assert_allclose(&s_got.data, &s_expect.data, 1e-4, 1e-5)
            .unwrap_or_else(|e| panic!("case {idx}: S^s mismatch: {e}"));

        // Output parity.
        let out_expect = f32s(case, "out");
        let out_got = spmm_alloc(&s, &v);
        assert_allclose(&out_got.data, &out_expect, 1e-3, 1e-4)
            .unwrap_or_else(|e| panic!("case {idx}: output mismatch: {e}"));

        // Full-density case must equal the dense reference too.
        if mask.density() == 1.0 {
            let dense_expect = f32s(case, "dense_out");
            assert_allclose(&out_got.data, &dense_expect, 1e-3, 1e-4)
                .unwrap_or_else(|e| panic!("case {idx}: dense parity: {e}"));
        }
    }
}
