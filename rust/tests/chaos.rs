//! Chaos suite — the only place the process-global fault registry is
//! armed. Production code trips `ckpt-write`/`io-err` inside checkpoint
//! save/load and `worker-panic`/`queue-slow` inside serve workers, so any
//! test that arms would poison concurrently-running trainer/engine tests
//! in the same process. This integration binary is its own process, and
//! every test here serializes on one gate, so arming is safe.
//!
//! Covered invariants (the PR-7 acceptance gates):
//! * determinism of the fault registry itself (seeded stream, `after`
//!   gating, counter resets);
//! * a crash injected between checkpoint staging and rename leaves the
//!   previous checkpoint intact;
//! * an injected read fault surfaces as a typed load error;
//! * under injected worker panics every admitted ticket still resolves
//!   exactly once, only the poisoned request fails, and the engine keeps
//!   serving (respawn) until the budget is exhausted (degraded);
//! * deadline shedding is reachable and counted when workers stall;
//! * an interrupted-then-resumed training run is bit-identical to the
//!   uninterrupted one;
//! * a shutdown request (the library face of SIGTERM) stops training at
//!   the step boundary with a forced resumable checkpoint, and SIGTERM
//!   to a real `spion train` process exits 0 with that checkpoint;
//! * retention pruning under injected `io-err` deletes never touches the
//!   newest valid checkpoint, and a torn `.tmp` staging file left by a
//!   crash in the `ckpt-write` window is swept (never loaded) on the
//!   next run.

use spion::config::types::SparsityConfig;
use spion::config::{ExperimentConfig, ModelConfig, PatternKind, TaskKind, TrainConfig};
use spion::coordinator::checkpoint::Checkpoint;
use spion::coordinator::NativeTrainer;
use spion::exec::ExecConfig;
use spion::model::{Encoder, ModelParams};
use spion::pattern::{BlockMask, SpionVariant};
use spion::resil;
use spion::resil::fault::{self, FaultPoint, ResilConfig};
use spion::serve::{Engine, ServeConfig, ServeError, MAX_WORKER_RESPAWNS};
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Every test takes this gate: the fault registry and the resil counters
/// are process-global, so chaos tests must not overlap.
static GATE: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII disarm: a panicking assertion must not leave the registry armed
/// for the next test.
struct DisarmGuard;

impl Drop for DisarmGuard {
    fn drop(&mut self) {
        fault::disarm();
    }
}

fn arm(points: &[&str], prob: f64, after: u64, seed: u64) -> DisarmGuard {
    fault::arm(&ResilConfig {
        faults: points.iter().map(|s| s.to_string()).collect(),
        prob,
        after,
        seed,
        kill: false,
    })
    .expect("valid arming config");
    DisarmGuard
}

fn tmp(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("spion-chaos-{}-{name}", std::process::id()))
        .to_str()
        .expect("utf8 temp path")
        .to_string()
}

// ---------------------------------------------------------------------------
// Fault-registry semantics (ported from the former fault.rs unit tests —
// they arm, so they must live in this process).
// ---------------------------------------------------------------------------

#[test]
fn armed_point_fires_and_counts() {
    let _g = locked();
    let _d = arm(&["ckpt-write"], 1.0, 0, 1);
    assert!(fault::armed());
    assert!(fault::trip(FaultPoint::CkptWrite), "armed point at prob 1 fires");
    assert!(!fault::trip(FaultPoint::WorkerPanic), "unarmed point never fires");
    assert_eq!(fault::hit_count(FaultPoint::CkptWrite), 1);
    assert_eq!(fault::fired_count(FaultPoint::CkptWrite), 1);
    assert_eq!(fault::hit_count(FaultPoint::WorkerPanic), 0);
    fault::disarm();
    assert!(!fault::trip(FaultPoint::CkptWrite), "disarmed registry is inert");
}

#[test]
fn after_gates_the_first_hits() {
    let _g = locked();
    let _d = arm(&["io-err"], 1.0, 3, 1);
    assert!(!fault::trip(FaultPoint::IoErr), "hit 1 < after 3");
    assert!(!fault::trip(FaultPoint::IoErr), "hit 2 < after 3");
    assert!(fault::trip(FaultPoint::IoErr), "hit 3 fires");
    assert!(fault::trip(FaultPoint::IoErr), "hits past after keep firing at prob 1");
    assert_eq!(fault::fired_count(FaultPoint::IoErr), 2);
}

#[test]
fn probability_stream_is_deterministic() {
    let _g = locked();
    let run = || -> Vec<bool> {
        let _d = arm(&["queue-slow"], 0.5, 0, 7);
        (0..64).map(|_| fault::trip(FaultPoint::QueueSlow)).collect()
    };
    let a = run();
    let fired = a.iter().filter(|&&f| f).count();
    // A fair-ish coin over 64 draws: a degenerate stream (all/none) would
    // mean the probability gate is broken.
    assert!(fired > 8 && fired < 56, "prob 0.5 fired {fired}/64");
    let b = run();
    assert_eq!(a, b, "same seed ⇒ same firing sequence");
}

#[test]
fn rearming_resets_counters() {
    let _g = locked();
    let _d = arm(&["ckpt-write"], 1.0, 0, 3);
    fault::trip(FaultPoint::CkptWrite);
    fault::trip(FaultPoint::CkptWrite);
    assert_eq!(fault::hit_count(FaultPoint::CkptWrite), 2);
    let _d = arm(&["ckpt-write"], 1.0, 0, 3);
    assert_eq!(fault::hit_count(FaultPoint::CkptWrite), 0, "re-arm resets hits");
    assert_eq!(fault::fired_count(FaultPoint::CkptWrite), 0, "re-arm resets fired");
}

#[test]
fn env_arming_roundtrip() {
    let _g = locked();
    // Unset → no-op, stays disarmed.
    std::env::remove_var("SPION_FAULTS");
    fault::arm_from_env().expect("unset env is a no-op");
    assert!(!fault::armed());
    // Set → armed with the parsed knobs; a typo'd point is a hard error.
    std::env::set_var("SPION_FAULTS", "queue-slow, io-err");
    std::env::set_var("SPION_FAULT_AFTER", "2");
    let _d = DisarmGuard;
    fault::arm_from_env().expect("valid env arms");
    assert!(fault::armed());
    assert!(!fault::trip(FaultPoint::QueueSlow), "after=2 gates the first hit");
    assert!(fault::trip(FaultPoint::QueueSlow));
    std::env::set_var("SPION_FAULTS", "no-such-point");
    assert!(fault::arm_from_env().is_err(), "unknown point must not silently disarm");
    std::env::remove_var("SPION_FAULTS");
    std::env::remove_var("SPION_FAULT_AFTER");
}

// ---------------------------------------------------------------------------
// Checkpoint crash-safety under injected faults.
// ---------------------------------------------------------------------------

fn small_checkpoint(preset: &str) -> Checkpoint {
    Checkpoint {
        preset: preset.into(),
        step: 3,
        tensors: vec![(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0])],
        masks: None,
        resume: None,
    }
}

#[test]
fn crashed_save_leaves_previous_checkpoint_intact() {
    let _g = locked();
    let path = tmp("atomic.ckpt");
    small_checkpoint("old").save(&path).expect("clean save");
    {
        let _d = arm(&["ckpt-write"], 1.0, 0, 1);
        let err = small_checkpoint("new").save(&path).expect_err("injected write fault");
        assert!(format!("{err:#}").contains("ckpt-write"), "{err:#}");
    }
    // The staged tmp never replaced the destination: the previous
    // checkpoint still loads, byte-for-byte valid.
    let back = Checkpoint::load(&path).expect("previous checkpoint intact");
    assert_eq!(back.preset, "old");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(format!("{path}.tmp")).ok();
}

#[test]
fn injected_read_fault_is_a_typed_load_error() {
    let _g = locked();
    let path = tmp("ioerr.ckpt");
    small_checkpoint("x").save(&path).expect("clean save");
    {
        let _d = arm(&["io-err"], 1.0, 0, 1);
        let err = Checkpoint::load(&path).expect_err("injected read fault");
        assert!(format!("{err:#}").contains("io-err"), "{err:#}");
    }
    assert_eq!(Checkpoint::load(&path).expect("disarmed load succeeds").preset, "x");
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Serve-side supervision: panics, respawn budget, deadlines.
// ---------------------------------------------------------------------------

/// Small sparse encoder through the public surface (L=32, 2 layers).
fn encoder(seed: u64) -> Encoder {
    let model = ModelConfig {
        preset: "chaos-test".into(),
        seq_len: 32,
        d_model: 32,
        heads: 2,
        layers: 2,
        ffn_dim: 64,
        vocab: 20,
        classes: 4,
        batch: 1,
    };
    let params = ModelParams::init_random(&model, seed);
    let mut m = BlockMask::empty(8, 4);
    m.set_diagonal();
    Encoder::new(params, 2).with_masks(vec![m.clone(), m]).expect("valid masks")
}

fn toks(seed: usize) -> Vec<i32> {
    (0..32).map(|t| ((t + seed) % 20) as i32).collect()
}

#[test]
fn worker_panic_fails_only_the_poisoned_request() {
    let _g = locked();
    let respawns_before = resil::stats().worker_respawns.load(Ordering::Relaxed);
    let eng = Engine::start(
        encoder(11),
        ServeConfig { workers: 1, max_batch: 1, ..Default::default() },
    )
    .expect("engine starts");

    let poisoned = {
        let _d = arm(&["worker-panic"], 1.0, 0, 1);
        eng.submit(toks(0)).expect("admitted").wait()
    };
    match poisoned {
        Err(ServeError::WorkerFailed { reason }) => {
            assert!(reason.contains("worker-panic"), "{reason}");
        }
        other => panic!("expected WorkerFailed, got {other:?}"),
    }

    // Disarmed again: the respawned worker serves the very next request.
    let ok = eng.submit(toks(1)).expect("admitted").wait().expect("served after respawn");
    assert_eq!(ok.logits.len(), 4);

    let stats = eng.stats();
    assert_eq!(stats.failed.load(Ordering::Relaxed), 1);
    assert_eq!(stats.served.load(Ordering::Relaxed), 1);
    assert_eq!(stats.admitted.load(Ordering::Relaxed), 2, "conservation: 2 admitted = 1 + 1");
    assert!(
        resil::stats().worker_respawns.load(Ordering::Relaxed) > respawns_before,
        "respawn was counted"
    );
    assert_eq!(eng.health().load(Ordering::Relaxed), resil::HEALTH_OK, "one panic ≠ degraded");
    eng.shutdown();
    assert_eq!(eng.health().load(Ordering::Relaxed), resil::HEALTH_DRAINING);
}

#[test]
fn exhausted_respawn_budget_degrades_health() {
    let _g = locked();
    let eng = Engine::start(
        encoder(12),
        ServeConfig { workers: 1, max_batch: 1, ..Default::default() },
    )
    .expect("engine starts");
    let _d = arm(&["worker-panic"], 1.0, 0, 1);

    // MAX_WORKER_RESPAWNS failures consume the budget; one more retires
    // the worker. Sequential waits keep each failure in its own batch.
    let failures = MAX_WORKER_RESPAWNS + 1;
    for i in 0..failures {
        let r = eng.submit(toks(i as usize)).expect("admitted").wait();
        assert!(
            matches!(r, Err(ServeError::WorkerFailed { .. })),
            "request {i} should fail under prob-1 worker-panic, got {r:?}"
        );
    }
    // The degraded store happens just after the final resolve; poll
    // briefly rather than racing it.
    let deadline = Instant::now() + Duration::from_secs(2);
    while eng.health().load(Ordering::Relaxed) != resil::HEALTH_DEGRADED {
        assert!(Instant::now() < deadline, "health never degraded after budget exhaustion");
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = eng.stats();
    assert_eq!(stats.failed.load(Ordering::Relaxed), failures);
    assert_eq!(stats.admitted.load(Ordering::Relaxed), failures, "every ticket resolved");
    eng.shutdown();
    // Shutdown owns the terminal state even for a degraded engine.
    assert_eq!(eng.health().load(Ordering::Relaxed), resil::HEALTH_DRAINING);
}

#[test]
fn stalled_worker_sheds_expired_deadlines() {
    let _g = locked();
    let shed_before = resil::stats().deadline_shed.load(Ordering::Relaxed);
    // queue-slow stalls every batch 25 ms; a 5 ms deadline therefore
    // expires before any forward starts — deterministically.
    let eng = Engine::start(
        encoder(13),
        ServeConfig { workers: 1, max_batch: 1, deadline_us: 5_000, ..Default::default() },
    )
    .expect("engine starts");
    let _d = arm(&["queue-slow"], 1.0, 0, 1);
    let tickets: Vec<_> = (0..4).map(|i| eng.submit(toks(i)).expect("admitted")).collect();
    for t in &tickets {
        assert_eq!(t.wait().expect_err("expired before execution"), ServeError::DeadlineExceeded);
    }
    let stats = eng.stats();
    assert_eq!(stats.served.load(Ordering::Relaxed), 0);
    assert_eq!(stats.failed.load(Ordering::Relaxed), 4);
    assert!(
        resil::stats().deadline_shed.load(Ordering::Relaxed) >= shed_before + 4,
        "deadline sheds were counted"
    );
    eng.shutdown();
}

// ---------------------------------------------------------------------------
// Interrupted-then-resumed training is bit-identical.
// ---------------------------------------------------------------------------

fn micro_exp(steps: usize, workers: usize) -> ExperimentConfig {
    let model = ModelConfig {
        preset: "micro".into(),
        seq_len: 32,
        d_model: 16,
        heads: 2,
        layers: 2,
        ffn_dim: 32,
        vocab: 20,
        classes: 10,
        batch: 4,
    };
    let train = TrainConfig {
        steps,
        lr: 0.02,
        min_dense_steps: 4,
        max_dense_steps: 8,
        snapshot_every: 2,
        ..Default::default()
    };
    let mut sparsity = SparsityConfig::new(PatternKind::Spion(SpionVariant::CF), 8, 0.7);
    sparsity.pattern.filter = 3;
    ExperimentConfig {
        task: TaskKind::ListOps,
        model,
        train,
        sparsity,
        exec: ExecConfig::with_workers(workers),
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        dist: Default::default(),
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn resumed_run_is_bit_identical_to_uninterrupted() {
    let _g = locked();
    let resumes_before = resil::stats().resume_total.load(Ordering::Relaxed);
    let golden = NativeTrainer::new(micro_exp(12, 2))
        .expect("golden trainer")
        .run()
        .expect("golden run");

    // "Crash" after step 5: run with periodic checkpoints, then restart
    // from the step-5 file as `spion train --resume` would.
    let base = tmp("resume.ckpt");
    let mut exp = micro_exp(12, 2);
    exp.train.checkpoint_every = Some(5);
    NativeTrainer::new(exp)
        .expect("interrupted trainer")
        .checkpoint_to(&base)
        .run()
        .expect("interrupted run");
    let ck = Checkpoint::load(&format!("{base}.step00000005")).expect("periodic checkpoint");
    assert!(ck.resume.is_some(), "periodic checkpoints carry a resume section");

    let resumed = NativeTrainer::new(micro_exp(12, 2))
        .expect("resumed trainer")
        .run_resumed(&ck)
        .expect("resumed run");
    assert!(
        resil::stats().resume_total.load(Ordering::Relaxed) > resumes_before,
        "resume was counted"
    );

    // The combined trajectory matches the uninterrupted one exactly —
    // losses, accuracies, phase boundaries, masks, final parameters.
    // (step_ms is wall time and legitimately differs.)
    assert_eq!(resumed.metrics.records.len(), golden.metrics.records.len());
    for (r, g) in resumed.metrics.records.iter().zip(&golden.metrics.records) {
        assert_eq!(r.step, g.step);
        assert_eq!(r.phase, g.phase, "phase diverged at step {}", g.step);
        assert_eq!(r.loss.to_bits(), g.loss.to_bits(), "loss diverged at step {}", g.step);
        assert_eq!(r.acc.to_bits(), g.acc.to_bits(), "acc diverged at step {}", g.step);
    }
    assert_eq!(resumed.metrics.transition_step, golden.metrics.transition_step);
    assert_eq!(resumed.masks, golden.masks);
    assert_eq!(resumed.final_params, golden.final_params, "final parameters diverged");

    // Cleanup the retained periodic checkpoints.
    for done in [5usize, 10] {
        std::fs::remove_file(format!("{base}.step{done:08}")).ok();
    }
}

// ---------------------------------------------------------------------------
// Graceful shutdown (SIGTERM): stop at the step boundary, resumable,
// bit-identical.
// ---------------------------------------------------------------------------

/// RAII clear: a panicking assertion must not leave the process-global
/// shutdown flag set for the next test (or the engine suites).
struct ClearShutdown;

impl Drop for ClearShutdown {
    fn drop(&mut self) {
        resil::clear_shutdown();
    }
}

#[test]
fn shutdown_request_stops_training_resumably_and_bit_identically() {
    let _g = locked();
    let golden = NativeTrainer::new(micro_exp(12, 2))
        .expect("golden trainer")
        .run()
        .expect("golden run");

    // Shutdown requested before the run starts: the driver honors it at
    // the first step boundary — step 0 completes fully, a checkpoint is
    // forced (checkpoint_every is None here), and the run returns early.
    let base = tmp("shutdown.ckpt");
    let _c = ClearShutdown;
    resil::request_shutdown();
    let interrupted = NativeTrainer::new(micro_exp(12, 2))
        .expect("interrupted trainer")
        .checkpoint_to(&base)
        .run()
        .expect("shutdown is a clean early return, not an error");
    assert_eq!(interrupted.metrics.records.len(), 1, "stopped after the in-flight step");
    let r = &interrupted.metrics.records[0];
    let g = &golden.metrics.records[0];
    assert_eq!(r.loss.to_bits(), g.loss.to_bits(), "the completed step matches the golden one");

    resil::clear_shutdown();
    let ck = Checkpoint::load(&format!("{base}.step00000001")).expect("forced final checkpoint");
    assert!(ck.resume.is_some(), "the shutdown checkpoint carries a resume section");

    let resumed = NativeTrainer::new(micro_exp(12, 2))
        .expect("resumed trainer")
        .run_resumed(&ck)
        .expect("resumed run");
    assert_eq!(resumed.metrics.records.len(), golden.metrics.records.len());
    for (r, g) in resumed.metrics.records.iter().zip(&golden.metrics.records) {
        assert_eq!(r.step, g.step);
        assert_eq!(r.phase, g.phase, "phase diverged at step {}", g.step);
        assert_eq!(r.loss.to_bits(), g.loss.to_bits(), "loss diverged at step {}", g.step);
        assert_eq!(r.acc.to_bits(), g.acc.to_bits(), "acc diverged at step {}", g.step);
    }
    assert_eq!(resumed.metrics.transition_step, golden.metrics.transition_step);
    assert_eq!(
        resumed.metrics.eval_accuracy.map(f64::to_bits),
        golden.metrics.eval_accuracy.map(f64::to_bits),
        "eval accuracy diverged"
    );
    assert_eq!(resumed.masks, golden.masks);
    assert_eq!(resumed.final_params, golden.final_params, "final parameters diverged");
    std::fs::remove_file(format!("{base}.step00000001")).ok();
}

#[test]
#[cfg(unix)]
fn sigterm_train_process_writes_resumable_checkpoint_and_exits_zero() {
    let _g = locked();
    let base = tmp("sigterm.ckpt");
    // A run long enough that SIGTERM always lands mid-training; the
    // handler finishes the in-flight step and exits, so the child never
    // actually runs the full 2000 steps.
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_spion"))
        .args([
            "train",
            "--preset",
            "tiny",
            "--backend",
            "native",
            "--steps",
            "2000",
            "--workers",
            "2",
            "--checkpoint-out",
            &base,
        ])
        .env("SPION_EVAL_BATCHES", "1")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn spion train");
    // Give it time to install the handler and complete at least one step.
    std::thread::sleep(Duration::from_millis(1500));
    let kill = std::process::Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("run kill");
    assert!(kill.success(), "SIGTERM delivered");

    // Bounded wait: a hung child means the graceful path regressed.
    let deadline = Instant::now() + Duration::from_secs(90);
    let status = loop {
        if let Some(st) = child.try_wait().expect("poll child") {
            break st;
        }
        if Instant::now() >= deadline {
            let _ = std::process::Command::new("kill")
                .args(["-KILL", &child.id().to_string()])
                .status();
            panic!("spion train did not exit within 90 s of SIGTERM");
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(), "SIGTERM exit is clean, got {status:?}");

    let mut stdout = String::new();
    use std::io::Read as _;
    child
        .stdout
        .take()
        .expect("piped stdout")
        .read_to_string(&mut stdout)
        .expect("read child stdout");
    let step: usize = stdout
        .lines()
        .find_map(|l| l.split("resumable at step ").nth(1))
        .expect("child printed the resumable line")
        .trim()
        .parse()
        .expect("resumable line ends with the step count");
    assert!(step >= 1, "at least the in-flight step completed");

    let path = format!("{base}.step{step:08}");
    let ck = Checkpoint::load(&path).expect("SIGTERM checkpoint loads");
    assert_eq!(ck.step as usize, step);
    assert!(ck.resume.is_some(), "SIGTERM checkpoint carries a resume section");
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&base).ok(); // final outcome checkpoint from report_train
}

// ---------------------------------------------------------------------------
// Checkpoint retention hardening: injected delete faults and torn
// staging files never cost the newest valid checkpoint.
// ---------------------------------------------------------------------------

#[test]
fn retention_io_err_never_removes_newest_checkpoint() {
    let _g = locked();
    let base = tmp("retain.ckpt");
    let mut exp = micro_exp(12, 1);
    exp.train.checkpoint_every = Some(2);
    exp.train.checkpoint_keep = 2;
    // io-err trips only reads and retention deletes — never the save
    // path — so the run itself survives at prob 1: 6 checkpoints are
    // written (steps 2..12) and all 4 prune attempts are injected
    // failures that must leak the old file rather than kill the run.
    {
        let _d = arm(&["io-err"], 1.0, 0, 1);
        NativeTrainer::new(exp)
            .expect("trainer")
            .checkpoint_to(&base)
            .run()
            .expect("run survives injected delete faults");
        assert_eq!(fault::fired_count(FaultPoint::IoErr), 4, "one injection per prune attempt");
    }
    // Every checkpoint is still on disk — a failed delete never cascades
    // into removing anything else — and the newest one is valid.
    for done in [2usize, 4, 6, 8, 10, 12] {
        let path = format!("{base}.step{done:08}");
        assert!(std::path::Path::new(&path).exists(), "{path} was deleted");
    }
    let newest = Checkpoint::load(&format!("{base}.step00000012")).expect("newest checkpoint valid");
    assert!(newest.resume.is_some());
    for done in [2usize, 4, 6, 8, 10, 12] {
        std::fs::remove_file(format!("{base}.step{done:08}")).ok();
    }
}

#[test]
fn torn_tmp_staging_file_is_swept_and_never_loaded() {
    let _g = locked();
    let base = tmp("torn.ckpt");
    // A crash inside the ckpt-write window (tmp staged, rename skipped)
    // leaves exactly this shape behind.
    let torn = format!("{base}.step00000002.tmp");
    std::fs::write(&torn, b"torn staging bytes, not a valid checkpoint").expect("plant torn tmp");

    let mut exp = micro_exp(6, 1);
    exp.train.checkpoint_every = Some(3);
    let out = NativeTrainer::new(exp)
        .expect("trainer")
        .checkpoint_to(&base)
        .run()
        .expect("run with a stale tmp in the checkpoint dir");
    assert!(!std::path::Path::new(&torn).exists(), "stale staging file swept at startup");
    assert_eq!(out.metrics.records.len(), 6);

    // The sweep only touched `.tmp` names: the real periodic checkpoints
    // are intact and the garbage bytes never surfaced as a load.
    let ck = Checkpoint::load(&format!("{base}.step00000003")).expect("real checkpoint intact");
    assert!(ck.resume.is_some());
    for done in [3usize, 6] {
        std::fs::remove_file(format!("{base}.step{done:08}")).ok();
    }
}
