//! Fused-kernel parity — the determinism contract of `sparse::kernel`
//! (DESIGN.md §Microkernels & fusion), mirroring `tests/exec_parity.rs`:
//!
//! * **fused serial ↔ parallel**: bit-for-bit at workers {1, 2, 4} (block
//!   rows are the unit of work; per-row code is worker-independent);
//! * **fused scalar ↔ unfused**: bit-for-bit (with `simd` off the fused
//!   sweep reproduces the three-pass kernels' exact association);
//! * **fused SIMD ↔ unfused**: allclose only (the 8-lane SDDMM dot
//!   reassociates the sum), forward and backward, across the pattern zoo
//!   (SPION-C/F/CF, BigBird, Reformer/LSH) and block sizes {2, 4, 8} —
//!   covering the B=4/B=8 specialized dispatch and the generic sweep.

use spion::attention::{
    sparse_attention_train_with, sparse_mha_with, MhaWorkspace, TrainWorkspace,
};
use spion::exec::{Exec, ExecConfig, KernelConfig};
use spion::pattern::bigbird::bigbird;
use spion::pattern::lsh::lsh_pattern;
use spion::pattern::spion::{generate_pattern, synth_attention_scores, PatternConfig};
use spion::pattern::{BlockMask, SpionVariant};
use spion::tensor::Mat;
use spion::util::quickcheck::{assert_allclose, QuickCheck};
use spion::util::rng::Rng;

fn exec_with(workers: usize, kernel: KernelConfig) -> Exec {
    Exec::new(ExecConfig { workers, kernel, ..Default::default() })
}

const FUSED_SIMD: KernelConfig = KernelConfig { fused: true, simd: true, fused_bwd: true };
const FUSED_SCALAR: KernelConfig = KernelConfig { fused: true, simd: false, fused_bwd: true };
const UNFUSED: KernelConfig = KernelConfig { fused: false, simd: false, fused_bwd: false };

/// A pattern from every policy the engine supports, at block size `block`.
fn pattern_zoo(rng: &mut Rng, l: usize, block: usize) -> Vec<(String, BlockMask)> {
    let scores = synth_attention_scores(l, 0.8, 0.4, &[l / 3], 0.05, rng);
    let lb = l / block;
    let mut zoo = Vec::new();
    for variant in [SpionVariant::C, SpionVariant::F, SpionVariant::CF] {
        let cfg = PatternConfig { variant, block, filter: 5, alpha: 0.5 + 0.45 * rng.f64() };
        zoo.push((variant.name().to_string(), generate_pattern(&scores, &cfg)));
    }
    zoo.push(("BigBird".into(), bigbird(lb, block, &Default::default(), rng)));
    zoo.push(("Reformer".into(), lsh_pattern(&scores, block, &Default::default(), rng)));
    zoo
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

/// Run the full fwd+bwd train pass under `exec` and return the workspace.
fn train(
    exec: &Exec,
    mask: &BlockMask,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    cot: &Mat,
    scale: f32,
) -> TrainWorkspace {
    let mut ws = TrainWorkspace::new(mask, q.cols);
    sparse_attention_train_with(exec, q, k, v, scale, cot, &mut ws);
    ws
}

#[test]
fn fused_serial_parallel_bit_identical() {
    QuickCheck::new().cases(10).run("fused serial↔parallel", |rng| {
        let block = [4usize, 8][rng.below(2)];
        let lb = (16 / block).max(2) + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(10);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.9, rng);
        let k = Mat::random_normal(l, d, 0.9, rng);
        let v = Mat::random_normal(l, d, 0.9, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let ws_ref = train(&exec_with(1, FUSED_SIMD), &mask, &q, &k, &v, &cot, scale);
            for workers in [2usize, 4] {
                let ws = train(&exec_with(workers, FUSED_SIMD), &mask, &q, &k, &v, &cot, scale);
                let tag = format!("{name} w={workers}");
                assert_bits_eq(&ws.fwd.s.values, &ws_ref.fwd.s.values, &format!("probs {tag}"));
                assert_bits_eq(&ws.fwd.ctx.data, &ws_ref.fwd.ctx.data, &format!("ctx {tag}"));
                assert_bits_eq(&ws.dq.data, &ws_ref.dq.data, &format!("dQ {tag}"));
                assert_bits_eq(&ws.dk.data, &ws_ref.dk.data, &format!("dK {tag}"));
                assert_bits_eq(&ws.dv.data, &ws_ref.dv.data, &format!("dV {tag}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fused_scalar_bitwise_equals_unfused() {
    // With simd off, the fused sweep keeps the legacy association in every
    // reduction — the whole pipeline (fwd probabilities, context, and all
    // three gradients) must reproduce the three-pass kernels bit for bit.
    QuickCheck::new().cases(10).run("fused scalar = unfused", |rng| {
        let block = [2usize, 4, 8][rng.below(3)];
        let lb = (16 / block).max(2) + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(10);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.9, rng);
        let k = Mat::random_normal(l, d, 0.9, rng);
        let v = Mat::random_normal(l, d, 0.9, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let ws_ref = train(&exec_with(1, UNFUSED), &mask, &q, &k, &v, &cot, scale);
            for workers in [1usize, 2, 4] {
                let ws = train(&exec_with(workers, FUSED_SCALAR), &mask, &q, &k, &v, &cot, scale);
                let tag = format!("{name} B={block} w={workers}");
                assert_bits_eq(&ws.fwd.s.values, &ws_ref.fwd.s.values, &format!("probs {tag}"));
                assert_bits_eq(&ws.fwd.ctx.data, &ws_ref.fwd.ctx.data, &format!("ctx {tag}"));
                assert_bits_eq(&ws.dq.data, &ws_ref.dq.data, &format!("dQ {tag}"));
                assert_bits_eq(&ws.dk.data, &ws_ref.dk.data, &format!("dK {tag}"));
                assert_bits_eq(&ws.dv.data, &ws_ref.dv.data, &format!("dV {tag}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fused_simd_allclose_to_unfused_fwd_bwd() {
    QuickCheck::new().cases(10).run("fused simd ≈ unfused", |rng| {
        let block = [2usize, 4, 8][rng.below(3)];
        let lb = (16 / block).max(2) + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(12);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.9, rng);
        let k = Mat::random_normal(l, d, 0.9, rng);
        let v = Mat::random_normal(l, d, 0.9, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let ws_ref = train(&exec_with(1, UNFUSED), &mask, &q, &k, &v, &cot, scale);
            for workers in [1usize, 2, 4] {
                let ws = train(&exec_with(workers, FUSED_SIMD), &mask, &q, &k, &v, &cot, scale);
                for (what, got, want) in [
                    ("probs", &ws.fwd.s.values, &ws_ref.fwd.s.values),
                    ("ctx", &ws.fwd.ctx.data, &ws_ref.fwd.ctx.data),
                    ("dq", &ws.dq.data, &ws_ref.dq.data),
                    ("dk", &ws.dk.data, &ws_ref.dk.data),
                    ("dv", &ws.dv.data, &ws_ref.dv.data),
                ] {
                    assert_allclose(got, want, 1e-3, 1e-5).unwrap_or_else(|e| {
                        panic!("{name} B={block} {what} w={workers}: {e}")
                    });
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fused_mha_bit_identical_across_workers_and_allclose_to_unfused() {
    QuickCheck::new().cases(8).run("fused mha parity", |rng| {
        let heads = [1usize, 2, 4][rng.below(3)];
        let block = [4usize, 8][rng.below(2)];
        let lb = 3 + rng.below(3);
        let l = lb * block;
        let d = heads * (2 + rng.below(6));
        let q = Mat::random_normal(l, d, 1.0, rng);
        let k = Mat::random_normal(l, d, 1.0, rng);
        let v = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let mut ws_ref = MhaWorkspace::new(&mask, heads, d);
            let fused_ref = sparse_mha_with(&exec_with(1, FUSED_SIMD), &q, &k, &v, &mut ws_ref)
                .clone();
            // Bit-identical across worker counts (head-parallel and
            // block-row-parallel schedules both).
            for workers in [2usize, 4] {
                let mut ws = MhaWorkspace::new(&mask, heads, d);
                let got = sparse_mha_with(&exec_with(workers, FUSED_SIMD), &q, &k, &v, &mut ws);
                assert_bits_eq(
                    &got.data,
                    &fused_ref.data,
                    &format!("fused mha {name} h={heads} w={workers}"),
                );
            }
            // Allclose to the unfused engine.
            let mut ws_u = MhaWorkspace::new(&mask, heads, d);
            let unfused = sparse_mha_with(&exec_with(1, UNFUSED), &q, &k, &v, &mut ws_u);
            assert_allclose(&fused_ref.data, &unfused.data, 1e-3, 1e-5)
                .unwrap_or_else(|e| panic!("fused↔unfused mha {name} h={heads}: {e}"));
        }
        Ok(())
    });
}

#[test]
fn workspace_steady_state_is_stable_across_repeated_steps() {
    // Repeated train steps through one workspace must be reproducible —
    // the arena + workspace reuse cannot leak state between steps.
    let mut rng = Rng::new(42);
    let (lb, block, d) = (4, 8, 8);
    let l = lb * block;
    let scale = 1.0 / (d as f32).sqrt();
    let q = Mat::random_normal(l, d, 0.9, &mut rng);
    let k = Mat::random_normal(l, d, 0.9, &mut rng);
    let v = Mat::random_normal(l, d, 0.9, &mut rng);
    let cot = Mat::random_normal(l, d, 1.0, &mut rng);
    let (_, mask) = pattern_zoo(&mut rng, l, block).remove(2); // SPION-CF
    let exec = exec_with(2, FUSED_SIMD);
    let mut ws = TrainWorkspace::new(&mask, d);
    sparse_attention_train_with(&exec, &q, &k, &v, scale, &cot, &mut ws);
    let first_dq = ws.dq.clone();
    let first_ctx = ws.fwd.ctx.clone();
    for _ in 0..5 {
        sparse_attention_train_with(&exec, &q, &k, &v, scale, &cot, &mut ws);
    }
    assert_bits_eq(&ws.dq.data, &first_dq.data, "dq drifted across steps");
    assert_bits_eq(&ws.fwd.ctx.data, &first_ctx.data, "ctx drifted across steps");
}
