//! Overload smoke suite — CI floods a tiny engine at several times its
//! capacity and asserts the three serving invariants the redesign exists
//! for:
//!
//! 1. **no hangs** — `try_submit` never blocks (bounded per-call wall
//!    time) and every `wait()` returns;
//! 2. **no lost tickets** — admitted + rejected = offered, and every
//!    admitted ticket resolves with a response or a typed error;
//! 3. **bounded memory** — the admission queue's high-water mark never
//!    exceeds `queue_depth`.
//!
//! The workload is deliberately lopsided: forwards on an L = 128 model
//! cost hundreds of µs while `try_submit` is lock-bound µs, so a flood of
//! 4× the engine's buffering capacity is guaranteed to hit `QueueFull`.

use spion::config::ModelConfig;
use spion::model::{Encoder, ModelParams};
use spion::pattern::BlockMask;
use spion::serve::{AdmissionError, Engine, ServeConfig, ServeError};
use spion::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// L = 128 sparse-diagonal encoder from the library's own initializer —
/// big enough that one forward costs hundreds of µs, dwarfing the
/// lock-bound `try_submit`.
fn encoder(seed: u64) -> Encoder {
    let model = ModelConfig {
        preset: "overload-test".into(),
        seq_len: 128,
        d_model: 32,
        heads: 2,
        layers: 2,
        ffn_dim: 64,
        vocab: 20,
        classes: 4,
        batch: 1,
    };
    let params = ModelParams::init_random(&model, seed);
    let mut mask = BlockMask::empty(8, 16);
    mask.set_diagonal();
    Encoder::new(params, 2).with_masks(vec![mask.clone(), mask]).unwrap()
}

fn toks(rng: &mut Rng) -> Vec<i32> {
    (0..128).map(|_| rng.below(20) as i32).collect()
}

#[test]
fn flood_at_4x_capacity_sheds_but_never_blocks_or_loses() {
    let cfg = ServeConfig { queue_depth: 8, max_batch: 2, workers: 1, ..Default::default() };
    // Buffering capacity: queue_depth + (2 × workers) formed batches of
    // max_batch + one batch on the worker. Offer 4× that.
    let capacity = cfg.queue_depth + 2 * cfg.max_batch + cfg.max_batch;
    let offered_total = 4 * capacity * 4; // 4 threads × 4× capacity each
    let engine = Arc::new(Engine::start(encoder(77), cfg).unwrap());

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let engine = engine.clone();
        let per_thread = offered_total / 4;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t);
            let mut tickets = Vec::new();
            let mut rejected = 0usize;
            let mut slowest = Duration::ZERO;
            for _ in 0..per_thread {
                let req = toks(&mut rng);
                let t0 = Instant::now();
                match engine.try_submit(req) {
                    Ok(tk) => tickets.push(tk),
                    Err(AdmissionError::QueueFull) => rejected += 1,
                    Err(e) => panic!("unexpected admission error: {e}"),
                }
                slowest = slowest.max(t0.elapsed());
            }
            (tickets, rejected, slowest)
        }));
    }

    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    let mut slowest = Duration::ZERO;
    for h in handles {
        let (t, r, s) = h.join().unwrap();
        tickets.extend(t);
        rejected += r;
        slowest = slowest.max(s);
    }

    // (2) conservation at the front door…
    assert_eq!(tickets.len() + rejected, offered_total);
    assert!(rejected > 0, "4× overload must observe at least one QueueFull");
    let stats = engine.stats();
    assert_eq!(stats.admitted.load(Ordering::Relaxed) as usize, tickets.len());
    assert_eq!(stats.rejected.load(Ordering::Relaxed) as usize, rejected);
    // (1) try_submit is non-blocking: even under 4-thread contention a
    // call is lock-bound — a full second would mean it waited on the queue.
    assert!(slowest < Duration::from_secs(1), "try_submit blocked for {slowest:?}");
    // …and every admitted ticket resolves with a response (the engine is
    // still up, so nothing was shed).
    for t in &tickets {
        assert!(t.wait().is_ok(), "admitted ticket lost");
    }
    assert_eq!(stats.served.load(Ordering::Relaxed) as usize, tickets.len());
    // (3) bounded memory: the queue never outgrew its configured depth.
    let peak = stats.queue_peak.load(Ordering::Relaxed) as usize;
    assert!(peak <= 8, "admission queue peaked at {peak} > queue_depth 8");
    engine.shutdown();
}

#[test]
fn shutdown_mid_flood_resolves_every_ticket() {
    let mut rng = Rng::new(78);
    let engine = Engine::start(
        encoder(78),
        ServeConfig { queue_depth: 64, max_batch: 2, workers: 1, ..Default::default() },
    )
    .unwrap();
    let tickets: Vec<_> = (0..64).filter_map(|_| engine.try_submit(toks(&mut rng)).ok()).collect();
    assert!(!tickets.is_empty());
    // Shut down with most of the backlog undispatched: in-flight batches
    // complete, the rest resolves ShuttingDown — nothing vanishes, nothing
    // hangs.
    engine.shutdown();
    let (mut served, mut shed) = (0u64, 0u64);
    for t in &tickets {
        match t.wait() {
            Ok(r) => {
                assert_eq!(r.logits.len(), 4);
                served += 1;
            }
            Err(ServeError::ShuttingDown) => shed += 1,
            Err(other) => panic!("unexpected resolution without faults: {other}"),
        }
    }
    assert_eq!(served + shed, tickets.len() as u64);
    let stats = engine.stats();
    assert_eq!(stats.served.load(Ordering::Relaxed), served);
    assert_eq!(stats.shed.load(Ordering::Relaxed), shed);
    // Post-shutdown admission is a typed error, immediately.
    assert!(matches!(engine.try_submit(toks(&mut rng)), Err(AdmissionError::ShuttingDown)));
}
