//! Dist-backend gates — the ISSUE-10 acceptance suite.
//!
//! Holds the two hard invariants of `coordinator/dist/`:
//!
//! * **Determinism**: a multi-rank run (N ∈ {1, 2, 3}, thread mode over
//!   real localhost sockets) produces a bit-identical trajectory —
//!   per-step loss/acc bits, phase boundaries, transition step, captured
//!   masks, final parameters, eval accuracy — to the single-process
//!   native backend. This holds *across* rank deaths, respawns and
//!   degraded resharding, because a step is a barrier: the optimizer is
//!   only applied once every shard arrived, so a replayed step is exact.
//! * **Supervision**: injected `rank-kill` / `conn-drop` / `rank-slow`
//!   faults are observed as rank deaths, the supervisor respawns under
//!   its budget (or retires the rank and degrades training health), and
//!   the retry counters the Prometheus `spion_dist_*` families export
//!   actually move.
//!
//! Like tests/chaos.rs this binary arms the process-global fault
//! registry, so every test serializes on one gate and disarms via RAII.
//! Rank-level faults are scoped to one rank with `SPION_DIST_FAULT_RANK`
//! (in thread mode the registry is shared with the coordinator — the
//! env gate is what keeps the blast radius to the chosen rank).

use spion::config::types::SparsityConfig;
use spion::config::{
    DistConfig, ExperimentConfig, ModelConfig, PatternKind, RankMode, TaskKind, TrainConfig,
};
use spion::coordinator::dist::{self, DistBackend};
use spion::coordinator::{run_training, NativeTrainer, TrainOutcome};
use spion::exec::ExecConfig;
use spion::pattern::SpionVariant;
use spion::resil;
use spion::resil::fault::{self, ResilConfig};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Every test takes this gate: the fault registry, the dist counters and
/// the train-health flag are process-global, so tests must not overlap.
static GATE: Mutex<()> = Mutex::new(());

fn locked() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII disarm: a panicking assertion must not leave the registry armed
/// for the next test.
struct DisarmGuard;

impl Drop for DisarmGuard {
    fn drop(&mut self) {
        fault::disarm();
    }
}

fn arm(points: &[&str], prob: f64, after: u64, seed: u64) -> DisarmGuard {
    fault::arm(&ResilConfig {
        faults: points.iter().map(|s| s.to_string()).collect(),
        prob,
        after,
        seed,
        kill: false,
    })
    .expect("valid arming config");
    DisarmGuard
}

/// Scope `rank-kill`/`rank-slow` to one rank; unsets the env var on drop.
struct TargetRankGuard;

impl Drop for TargetRankGuard {
    fn drop(&mut self) {
        std::env::remove_var("SPION_DIST_FAULT_RANK");
    }
}

fn target_rank(rank: u32) -> TargetRankGuard {
    std::env::set_var("SPION_DIST_FAULT_RANK", rank.to_string());
    TargetRankGuard
}

/// Restore the global train-health flag (a retirement test degrades it).
struct HealthGuard;

impl Drop for HealthGuard {
    fn drop(&mut self) {
        resil::set_train_health(resil::HEALTH_OK);
    }
}

/// Cumulative dist counters at one instant; tests assert on deltas
/// because the stats are process-global and never reset.
#[derive(Clone, Copy)]
struct Counters {
    deaths: u64,
    respawns: u64,
    retired: u64,
    step_retries: u64,
}

fn counters() -> Counters {
    let s = dist::stats();
    Counters {
        deaths: s.rank_deaths.load(Relaxed),
        respawns: s.rank_respawns.load(Relaxed),
        retired: s.rank_retired.load(Relaxed),
        step_retries: s.step_retries.load(Relaxed),
    }
}

/// Same micro experiment as tests/chaos.rs, plus a `[dist]` section in
/// thread mode (real localhost sockets, ranks hosted as threads so the
/// seeded fault stream is shared and deterministic).
fn micro_exp(steps: usize, ranks: usize) -> ExperimentConfig {
    let model = ModelConfig {
        preset: "micro".into(),
        seq_len: 32,
        d_model: 16,
        heads: 2,
        layers: 2,
        ffn_dim: 32,
        vocab: 20,
        classes: 10,
        batch: 4,
    };
    let train = TrainConfig {
        steps,
        lr: 0.02,
        min_dense_steps: 4,
        max_dense_steps: 8,
        snapshot_every: 2,
        ..Default::default()
    };
    let mut sparsity = SparsityConfig::new(PatternKind::Spion(SpionVariant::CF), 8, 0.7);
    sparsity.pattern.filter = 3;
    ExperimentConfig {
        task: TaskKind::ListOps,
        model,
        train,
        sparsity,
        exec: ExecConfig::with_workers(1),
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        dist: DistConfig {
            ranks,
            mode: RankMode::Thread,
            heartbeat_timeout_ms: 2000,
            step_timeout_ms: 10_000,
            connect_timeout_ms: 2000,
            connect_retries: 4,
            backoff_base_ms: 5,
            backoff_max_ms: 50,
            respawn_budget: 2,
            step_retries: 6,
        },
        artifacts_dir: "artifacts".into(),
    }
}

/// The single-process native golden this suite compares everything to.
fn golden(steps: usize) -> TrainOutcome {
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    NativeTrainer::new(micro_exp(steps, 0))
        .expect("golden trainer")
        .run()
        .expect("golden run")
}

fn run_dist(exp: ExperimentConfig) -> TrainOutcome {
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let mut backend = DistBackend::new(exp).expect("dist backend starts");
    run_training(&mut backend, false, None, None).expect("dist run completes")
}

/// Full bit-compare against the golden outcome (step_ms is wall time and
/// legitimately differs; everything else must match exactly).
fn assert_matches_golden(out: &TrainOutcome, golden: &TrainOutcome, label: &str) {
    assert_eq!(
        out.metrics.records.len(),
        golden.metrics.records.len(),
        "{label}: record count diverged"
    );
    for (r, g) in out.metrics.records.iter().zip(&golden.metrics.records) {
        assert_eq!(r.step, g.step, "{label}: step index diverged");
        assert_eq!(r.phase, g.phase, "{label}: phase diverged at step {}", g.step);
        assert_eq!(
            r.loss.to_bits(),
            g.loss.to_bits(),
            "{label}: loss diverged at step {}",
            g.step
        );
        assert_eq!(
            r.acc.to_bits(),
            g.acc.to_bits(),
            "{label}: acc diverged at step {}",
            g.step
        );
    }
    assert_eq!(
        out.metrics.transition_step, golden.metrics.transition_step,
        "{label}: transition step diverged"
    );
    assert_eq!(
        out.metrics.eval_accuracy.map(f64::to_bits),
        golden.metrics.eval_accuracy.map(f64::to_bits),
        "{label}: eval accuracy diverged"
    );
    assert_eq!(out.masks, golden.masks, "{label}: masks diverged");
    assert_eq!(out.final_params, golden.final_params, "{label}: final params diverged");
}

/// Watcher thread that disarms the registry as soon as the coordinator
/// declares the first (post-baseline) rank death. A prob-1.0 stream
/// would otherwise also kill every respawned incarnation; disarming from
/// a side thread turns it into a fire-once injection. The race window is
/// ~1 ms of polling against a respawn that needs a TCP connect plus a
/// handshake roundtrip, so the respawned rank always runs disarmed.
fn disarm_on_first_death(deaths_before: u64) -> std::thread::JoinHandle<bool> {
    std::thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(60);
        while Instant::now() < deadline {
            if dist::stats().rank_deaths.load(Relaxed) > deaths_before {
                fault::disarm();
                return true;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        false
    })
}

// ---------------------------------------------------------------------------
// Determinism: N ranks ≡ single-process, bit for bit.
// ---------------------------------------------------------------------------

#[test]
fn thread_ranks_are_bit_identical_to_native_at_any_count() {
    let _g = locked();
    let golden = golden(12);
    let before = counters();
    for ranks in [1usize, 2, 3] {
        let out = run_dist(micro_exp(12, ranks));
        assert_matches_golden(&out, &golden, &format!("{ranks} rank(s)"));
    }
    let after = counters();
    assert_eq!(after.deaths, before.deaths, "clean runs must not declare deaths");
    assert_eq!(after.step_retries, before.step_retries, "clean runs must not replay steps");
}

// ---------------------------------------------------------------------------
// rank-kill: one injected death → respawn → replay, still bit-identical.
// ---------------------------------------------------------------------------

#[test]
fn rank_kill_respawns_and_replays_bit_identically() {
    let _g = locked();
    let golden = golden(12);
    let _env = target_rank(1);
    let before = counters();
    // Rank 1 dies on its 3rd step receipt; the watcher disarms at the
    // declared death so its respawned incarnation survives the replay.
    let _d = arm(&["rank-kill"], 1.0, 3, 1);
    let watcher = disarm_on_first_death(before.deaths);
    let out = run_dist(micro_exp(12, 3));
    assert!(watcher.join().expect("watcher thread"), "a rank death was observed");
    let after = counters();
    assert!(after.deaths > before.deaths, "rank-kill death was counted");
    assert!(after.respawns > before.respawns, "respawn was counted");
    assert_eq!(after.retired, before.retired, "budget was not exhausted");
    assert!(after.step_retries > before.step_retries, "interrupted step was replayed");
    assert_matches_golden(&out, &golden, "rank-kill + respawn");
}

// ---------------------------------------------------------------------------
// Budget exhaustion: retire the rank, reshard over survivors, degrade
// health — and *still* match the golden trajectory bit for bit.
// ---------------------------------------------------------------------------

#[test]
fn budget_exhaustion_retires_rank_degrades_health_and_stays_deterministic() {
    let _g = locked();
    let golden = golden(10);
    let _env = target_rank(1);
    let _h = HealthGuard;
    let mut exp = micro_exp(10, 3);
    exp.dist.respawn_budget = 1;
    let before = counters();
    // No watcher: at prob 1 every rank-1 step receipt from the 3rd on
    // fires, so the sequence is fully deterministic — incarnation 1 dies
    // at hit 3, the respawned one at hit 4, the budget (1) is spent, and
    // the rank is retired. Ranks 0 and 2 never trip (env gate).
    let _d = arm(&["rank-kill"], 1.0, 3, 1);
    let out = run_dist(exp);
    let after = counters();
    assert_eq!(after.deaths - before.deaths, 2, "exactly two deaths: original + respawn");
    assert_eq!(after.respawns - before.respawns, 1, "one respawn before the budget ran out");
    assert_eq!(after.retired - before.retired, 1, "rank 1 was retired");
    assert_eq!(
        resil::train_health(),
        resil::HEALTH_DEGRADED,
        "retirement degrades training health"
    );
    assert_matches_golden(&out, &golden, "degraded reshard over 2 survivors");
}

// ---------------------------------------------------------------------------
// conn-drop: a torn frame (either direction) is a detected death, the
// step replays from the barrier, trajectory unchanged.
// ---------------------------------------------------------------------------

#[test]
fn conn_drop_is_survived_and_stays_bit_identical() {
    let _g = locked();
    let golden = golden(12);
    let mut exp = micro_exp(12, 3);
    // Margin for the cascade window between the first torn frame and the
    // watcher's disarm (prob 1 means every write in that window fails).
    exp.dist.respawn_budget = 5;
    exp.dist.step_retries = 8;
    let before = counters();
    // after=10 skips the 6 handshake frames (3 Hello + 3 Welcome), so
    // the first torn frame lands mid-step — a Params/Step/Grads or
    // heartbeat write, whichever thread draws hit 10.
    let _d = arm(&["conn-drop"], 1.0, 10, 1);
    let watcher = disarm_on_first_death(before.deaths);
    let out = run_dist(exp);
    assert!(watcher.join().expect("watcher thread"), "a torn frame was observed as a death");
    let after = counters();
    assert!(after.deaths > before.deaths, "conn-drop death was counted");
    assert!(after.step_retries > before.step_retries, "interrupted step was replayed");
    assert_matches_golden(&out, &golden, "conn-drop + replay");
}

// ---------------------------------------------------------------------------
// rank-slow: a stalled rank trips the *step* deadline (heartbeats keep
// the liveness deadline fresh), is respawned, trajectory unchanged.
// ---------------------------------------------------------------------------

#[test]
fn rank_slow_trips_step_deadline_and_stays_bit_identical() {
    let _g = locked();
    let golden = golden(10);
    let _env = target_rank(2);
    let mut exp = micro_exp(10, 3);
    // The injected stall is 750 ms; a 300 ms step deadline makes the
    // collect abandon the stalled rank while its heartbeat thread is
    // still live — this is the deadline the heartbeat cannot mask.
    exp.dist.step_timeout_ms = 300;
    exp.dist.respawn_budget = 5;
    let before = counters();
    let _d = arm(&["rank-slow"], 1.0, 2, 1);
    let watcher = disarm_on_first_death(before.deaths);
    let out = run_dist(exp);
    assert!(watcher.join().expect("watcher thread"), "the stall was observed as a death");
    let after = counters();
    assert!(after.deaths > before.deaths, "step-deadline death was counted");
    assert!(after.step_retries > before.step_retries, "stalled step was replayed");
    assert_matches_golden(&out, &golden, "rank-slow + step-deadline replay");
}
