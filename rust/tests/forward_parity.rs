//! Cross-path parity witnesses for the unified layer-stage pipeline.
//!
//! The serve path (`Encoder::forward`) and the train path
//! (`train_step_sample`) both run `model::layer::forward_pipeline`; these
//! tests pin the refactor's gate: logits bit-identical across the two
//! paths (dense and block-sparse, at worker counts 1/2/4), captured A^s
//! bit-identical across modes, and `SPIONRS1` periodic checkpoints written
//! before the refactor-shaped trainer still load and continue
//! bit-identically.

use spion::config::types::SparsityConfig;
use spion::config::{
    ExperimentConfig, ModelConfig, PatternKind, TaskKind, TrainConfig,
};
use spion::coordinator::checkpoint::Checkpoint;
use spion::coordinator::NativeTrainer;
use spion::exec::{Exec, ExecConfig};
use spion::model::{train_step_sample, Encoder, ModelGrads, ModelParams};
use spion::pattern::{BlockMask, SpionVariant};
use spion::util::rng::Rng;

fn micro_model() -> ModelConfig {
    ModelConfig {
        preset: "micro".into(),
        seq_len: 32,
        d_model: 16,
        heads: 2,
        layers: 2,
        ffn_dim: 32,
        vocab: 20,
        classes: 10,
        batch: 4,
    }
}

fn micro_exp(kind: PatternKind, steps: usize, workers: usize) -> ExperimentConfig {
    let train = TrainConfig {
        steps,
        lr: 0.02,
        min_dense_steps: 4,
        max_dense_steps: 8,
        snapshot_every: 2,
        ..Default::default()
    };
    let mut sparsity = SparsityConfig::new(kind, 8, 0.7);
    sparsity.pattern.filter = 3;
    ExperimentConfig {
        task: TaskKind::ListOps,
        model: micro_model(),
        train,
        sparsity,
        exec: ExecConfig::with_workers(workers),
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        dist: Default::default(),
        artifacts_dir: "artifacts".into(),
    }
}

fn micro_tokens(l: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..l).map(|_| rng.below(vocab) as i32).collect()
}

/// Layer-wise block masks with realistic structure (diagonal + vertical),
/// generated through the real pattern dispatch.
fn micro_masks(m: &ModelConfig) -> Vec<BlockMask> {
    let exp = micro_exp(PatternKind::Spion(SpionVariant::CF), 1, 1);
    let mut rng = Rng::new(7);
    let scores: Vec<_> = (0..m.layers)
        .map(|i| {
            spion::pattern::spion::synth_attention_scores(
                m.seq_len,
                1.0 - 0.5 * i as f32,
                0.5 * i as f32,
                &[m.seq_len / 3],
                0.05,
                &mut rng,
            )
        })
        .collect();
    let masks =
        spion::coordinator::trainer::generate_masks_for(&exp, &scores).expect("mask generation");
    assert!(masks.iter().any(|mk| mk.density() < 1.0), "masks should be sparse");
    masks
}

/// Serve-path logits for `tokens` on a `workers`-wide exec.
fn serve_logits(
    params: &ModelParams,
    heads: usize,
    masks: Option<&[BlockMask]>,
    tokens: &[i32],
    workers: usize,
) -> Vec<f32> {
    let mut enc = Encoder::new(params.clone(), heads)
        .with_exec(Exec::new(ExecConfig::with_workers(workers)));
    if let Some(ms) = masks {
        enc = enc.with_masks(ms.to_vec()).expect("masks fit the model");
    }
    enc.forward(tokens)
}

/// Train-path logits for the same tokens on the same exec width.
fn train_logits(
    params: &ModelParams,
    heads: usize,
    masks: Option<&[BlockMask]>,
    tokens: &[i32],
    workers: usize,
) -> Vec<f32> {
    let exec = Exec::new(ExecConfig::with_workers(workers));
    let mut grads = ModelGrads::zeros_like(params);
    train_step_sample(&exec, params, heads, masks, tokens, 0, false, &mut grads, None).logits
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: logit {i}: {x} vs {y}");
    }
}

#[test]
fn dense_serve_and_train_logits_bit_identical_across_workers() {
    let m = micro_model();
    let params = ModelParams::init_random(&m, 42);
    let toks = micro_tokens(m.seq_len, m.vocab, 3);
    let reference = serve_logits(&params, m.heads, None, &toks, 1);
    assert_eq!(reference.len(), m.classes);
    for workers in [1, 2, 4] {
        let serve = serve_logits(&params, m.heads, None, &toks, workers);
        let train = train_logits(&params, m.heads, None, &toks, workers);
        assert_bits_eq(&serve, &reference, &format!("dense serve w={workers}"));
        assert_bits_eq(&train, &reference, &format!("dense train w={workers}"));
    }
}

#[test]
fn sparse_serve_and_train_logits_bit_identical_across_workers() {
    let m = micro_model();
    let params = ModelParams::init_random(&m, 42);
    let toks = micro_tokens(m.seq_len, m.vocab, 3);
    let masks = micro_masks(&m);
    let reference = serve_logits(&params, m.heads, Some(&masks), &toks, 1);
    for workers in [1, 2, 4] {
        let serve = serve_logits(&params, m.heads, Some(&masks), &toks, workers);
        let train = train_logits(&params, m.heads, Some(&masks), &toks, workers);
        assert_bits_eq(&serve, &reference, &format!("sparse serve w={workers}"));
        assert_bits_eq(&train, &reference, &format!("sparse train w={workers}"));
    }
}

#[test]
fn captured_scores_bit_identical_across_modes() {
    // The transition detector's A^s must not depend on which mode captured
    // it: `Encoder::forward_captured` (Infer) vs the train-path snapshot.
    let m = micro_model();
    let params = ModelParams::init_random(&m, 42);
    let toks = micro_tokens(m.seq_len, m.vocab, 5);
    let mut enc = Encoder::new(params.clone(), m.heads);
    let (logits_cap, serve_scores) = enc.forward_captured(&toks);
    assert_bits_eq(&logits_cap, &enc.forward(&toks), "captured vs plain forward");
    let exec = Exec::new(ExecConfig::with_workers(1));
    let mut grads = ModelGrads::zeros_like(&params);
    let r = train_step_sample(&exec, &params, m.heads, None, &toks, 0, true, &mut grads, None);
    let train_scores = r.scores.expect("dense snapshot captures scores");
    assert_eq!(serve_scores.len(), m.layers);
    assert_eq!(train_scores.len(), m.layers);
    for (n, (a, b)) in serve_scores.iter().zip(&train_scores).enumerate() {
        assert_eq!((a.rows, a.cols), (m.seq_len, m.seq_len));
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "layer {n} A^s element {i}");
        }
    }
}

#[test]
fn resume_from_periodic_checkpoint_stays_bit_identical() {
    // Format + trajectory stability: a SPIONRS1 periodic checkpoint written
    // by the refactored trainer loads and continues to the exact golden
    // trajectory (losses, accuracies, transition, masks, final params).
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let base = std::env::temp_dir()
        .join("spion_forward_parity_resume.ckpt")
        .to_str()
        .expect("utf-8 temp path")
        .to_string();
    let kind = PatternKind::Spion(SpionVariant::CF);
    let golden = NativeTrainer::new(micro_exp(kind, 12, 1))
        .expect("valid micro config")
        .run()
        .expect("golden run");

    let mut exp = micro_exp(kind, 12, 1);
    exp.train.checkpoint_every = Some(6);
    NativeTrainer::new(exp)
        .expect("valid micro config")
        .checkpoint_to(&base)
        .run()
        .expect("checkpointed run");

    let ck_path = format!("{base}.step00000006");
    let raw = std::fs::read(&ck_path).expect("periodic checkpoint on disk");
    assert!(
        raw.windows(8).any(|w| w == b"SPIONRS1"),
        "periodic checkpoint carries a SPIONRS1 resume section"
    );
    let ck = Checkpoint::load(&ck_path).expect("checkpoint loads");
    assert!(ck.resume.is_some());

    let resumed = NativeTrainer::new(micro_exp(kind, 12, 1))
        .expect("valid micro config")
        .run_resumed(&ck)
        .expect("resumed run");

    assert_eq!(resumed.metrics.records.len(), golden.metrics.records.len());
    for (a, b) in golden.metrics.records.iter().zip(&resumed.metrics.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss at step {}", a.step);
        assert_eq!(a.acc.to_bits(), b.acc.to_bits(), "acc at step {}", a.step);
    }
    assert_eq!(resumed.metrics.transition_step, golden.metrics.transition_step);
    assert_eq!(resumed.masks, golden.masks);
    assert_eq!(resumed.final_params, golden.final_params);

    for suffix in ["step00000006", "step00000012"] {
        std::fs::remove_file(format!("{base}.{suffix}")).ok();
    }
}
