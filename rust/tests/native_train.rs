//! Native training backend: finite-difference gradient checks over the
//! *full encoder* (every parameter tensor, dense and sparse attention),
//! the three-phase end-to-end loop with no artifacts directory, the
//! checkpoint→serve mask round-trip, and (artifact-gated) a sanity
//! comparison against the PJRT backend's loss trajectory.

use spion::config::types::SparsityConfig;
use spion::config::{ExperimentConfig, ModelConfig, PatternKind, TaskKind, TrainConfig};
use spion::coordinator::checkpoint::Checkpoint;
use spion::coordinator::NativeTrainer;
use spion::exec::Exec;
use spion::metrics::Phase;
use spion::model::grad::{param_slices_mut, ModelGrads};
use spion::model::{train_step_sample, Encoder, ModelParams};
use spion::pattern::{BlockMask, SpionVariant};
use spion::serve::{BatchPolicy, InferenceServer};
use spion::util::rng::Rng;

/// Tiny-but-complete shape: 2 layers, 2 heads, uneven FFN width — small
/// enough that probing every tensor with central differences stays fast.
fn micro_model() -> ModelConfig {
    ModelConfig {
        preset: "micro".into(),
        seq_len: 8,
        d_model: 6,
        heads: 2,
        layers: 2,
        ffn_dim: 10,
        vocab: 9,
        classes: 3,
        batch: 2,
    }
}

fn micro_tokens(l: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..l).map(|_| rng.below(vocab) as i32).collect()
}

fn loss_of(
    exec: &Exec,
    params: &ModelParams,
    heads: usize,
    masks: Option<&[BlockMask]>,
    toks: &[i32],
    label: i32,
) -> f64 {
    let mut g = ModelGrads::zeros_like(params);
    train_step_sample(exec, params, heads, masks, toks, label, false, &mut g, None).loss
}

/// Probe a spread of coordinates in every parameter tensor with central
/// differences and compare against the analytic gradient.
fn fd_check_all_tensors(masks: Option<Vec<BlockMask>>) {
    let m = micro_model();
    let params = ModelParams::init_random(&m, 3);
    let toks = micro_tokens(m.seq_len, m.vocab, 17);
    let label = 1;
    let exec = Exec::serial();
    let masks_ref = masks.as_deref();

    let mut grads = ModelGrads::zeros_like(&params);
    train_step_sample(&exec, &params, m.heads, masks_ref, &toks, label, false, &mut grads, None);

    let eps = 1e-2f32;
    let mut pairs: Vec<(f64, f64)> = Vec::new(); // (finite-diff, analytic)
    let analytic: Vec<Vec<f32>> = grads.slices().into_iter().map(|s| s.to_vec()).collect();
    for (ti, g) in analytic.iter().enumerate() {
        let stride = (g.len() / 6).max(1);
        for idx in (0..g.len()).step_by(stride) {
            let probe = |delta: f32| -> f64 {
                let mut p = params.clone();
                param_slices_mut(&mut p)[ti][idx] += delta;
                loss_of(&exec, &p, m.heads, masks_ref, &toks, label)
            };
            let fd = (probe(eps) - probe(-eps)) / (2.0 * eps as f64);
            let an = g[idx] as f64;
            // Floor absorbs the f32-forward noise of the central difference
            // (~1e-4 at this eps); real sign/scale errors on any non-tiny
            // gradient still blow well past the threshold.
            let err = (fd - an).abs() / (1e-2 + fd.abs().max(an.abs()));
            assert!(
                err < 0.05,
                "tensor {ti} idx {idx}: finite-diff {fd:.6} vs analytic {an:.6} (rel {err:.4})"
            );
            pairs.push((fd, an));
        }
    }
    assert!(pairs.len() > 100, "probed only {} coordinates", pairs.len());
    // Global agreement: the two gradient vectors must point the same way.
    let dot: f64 = pairs.iter().map(|(a, b)| a * b).sum();
    let nf: f64 = pairs.iter().map(|(a, _)| a * a).sum::<f64>().sqrt();
    let na: f64 = pairs.iter().map(|(_, b)| b * b).sum::<f64>().sqrt();
    assert!(na > 0.0, "analytic gradient is identically zero");
    let cos = dot / (nf * na);
    assert!(cos > 0.995, "finite-diff vs analytic cosine similarity {cos}");
}

#[test]
fn full_encoder_gradients_match_finite_differences_dense() {
    fd_check_all_tensors(None);
}

#[test]
fn full_encoder_gradients_match_finite_differences_sparse() {
    // Block-diagonal + one off-diagonal block per layer (L=8, B=4 → lb=2).
    let mut m0 = BlockMask::empty(2, 4);
    m0.set_diagonal();
    m0.set(0, 1, true);
    let mut m1 = BlockMask::empty(2, 4);
    m1.set_diagonal();
    m1.set(1, 0, true);
    fd_check_all_tensors(Some(vec![m0, m1]));
}

fn micro_exp(kind: PatternKind, steps: usize, workers: usize) -> ExperimentConfig {
    let model = ModelConfig {
        preset: "micro".into(),
        seq_len: 32,
        d_model: 16,
        heads: 2,
        layers: 2,
        ffn_dim: 32,
        vocab: 20,
        classes: 10,
        batch: 4,
    };
    let train = TrainConfig {
        steps,
        lr: 0.02, // SGD+momentum step; Adam's 1e-3 default is too timid here
        min_dense_steps: 4,
        max_dense_steps: 8,
        snapshot_every: 2,
        ..Default::default()
    };
    let mut sparsity = SparsityConfig::new(kind, 8, 0.7);
    sparsity.pattern.filter = 3;
    ExperimentConfig {
        task: TaskKind::ListOps,
        model,
        train,
        sparsity,
        exec: spion::exec::ExecConfig::with_workers(workers),
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        dist: Default::default(),
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn native_three_phase_loop_decreases_loss_and_serves_trained_masks() {
    // NOTE: every test in this binary sets the same value — the tests run
    // on parallel threads and env vars are process-global, so differing
    // values would race.
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let exp = micro_exp(PatternKind::Spion(SpionVariant::CF), 40, 1);
    let trainer = NativeTrainer::new(exp).unwrap();
    let outcome = trainer.run().unwrap();
    let m = &outcome.metrics;

    // Phase structure: dense prefix, sparse suffix, one transition in the
    // configured window.
    let t = m.transition_step.expect("transition fired");
    assert!((4..=8).contains(&t), "transition at {t}");
    assert!(m.records.iter().take(t).all(|r| r.phase == Phase::Dense));
    assert!(m.records.iter().skip(t + 1).all(|r| r.phase == Phase::Sparse));

    // Masks: per layer, block-sparse, diagonal forced on.
    let masks = outcome.masks.as_ref().expect("masks generated");
    assert_eq!(masks.len(), 2);
    for mask in masks {
        assert!(mask.density() < 1.0, "density {}", mask.density());
        for k in 0..mask.lb {
            assert!(mask.get(k, k), "diagonal block {k}");
        }
    }

    // Optimization signal: the tail of the loss curve sits below the head.
    let first = m.records.first().unwrap().loss;
    let last_avg: f32 = m.records.iter().rev().take(5).map(|r| r.loss).sum::<f32>() / 5.0;
    assert!(last_avg < first, "loss did not decrease: {first} → {last_avg}");
    assert!(m.eval_accuracy.unwrap() >= 0.0);

    // Checkpoint round-trip: tensors AND masks survive, and the serve
    // stack runs the trained pattern.
    let path = std::env::temp_dir().join("spion_native_e2e.ckpt");
    let path = path.to_str().unwrap();
    trainer.save_checkpoint(&outcome, path).unwrap();
    let ck = Checkpoint::load(path).unwrap();
    assert_eq!(ck.preset, "micro");
    assert_eq!(ck.masks.as_ref(), outcome.masks.as_ref(), "trained masks persisted");
    let params = ModelParams::from_checkpoint(&ck, 2).unwrap();
    let enc = Encoder::new(params, 2).with_masks(ck.masks.unwrap()).unwrap();
    assert!(enc.is_sparse());
    let server = InferenceServer::start(enc, BatchPolicy::default());
    let toks = micro_tokens(32, 20, 4);
    let r = server.client().infer(toks).expect("served");
    assert_eq!(r.logits.len(), 10);
    assert!(r.logits.iter().all(|v| v.is_finite()));
    server.shutdown();
    std::fs::remove_file(path).ok();
}

#[test]
fn native_backend_runs_every_pattern_kind() {
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    for kind in PatternKind::all() {
        let exp = micro_exp(kind, 10, 1);
        let outcome = NativeTrainer::new(exp).unwrap().run().unwrap();
        assert!(
            outcome.metrics.final_loss().unwrap().is_finite(),
            "{} diverged",
            kind.name()
        );
        if matches!(kind, PatternKind::Dense) {
            assert!(outcome.masks.is_none());
        } else {
            assert!(outcome.metrics.transition_step.is_some(), "{}", kind.name());
        }
    }
}

/// Native vs PJRT: the two backends use different inits and optimizers
/// (SGD+momentum vs baked Adam), so trajectories are not bit-comparable —
/// but on the same preset both must start near ln(classes) and both must
/// optimize. Runs only when the AOT artifacts and a real XLA backend are
/// present; skips (like the other artifact-gated suites) otherwise.
#[test]
fn native_and_pjrt_loss_trajectories_agree_qualitatively() {
    if !std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        eprintln!("SKIP: artifacts/tiny missing — run `make artifacts`");
        return;
    }
    let rt = match spion::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT backend unavailable ({e:#})");
            return;
        }
    };
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let (task, model) = spion::config::types::preset("tiny").unwrap();
    let mk_exp = || {
        let train = TrainConfig {
            steps: 12,
            min_dense_steps: 4,
            max_dense_steps: 8,
            snapshot_every: 2,
            ..Default::default()
        };
        ExperimentConfig {
            task,
            model: model.clone(),
            train,
            sparsity: SparsityConfig::new(PatternKind::Spion(SpionVariant::CF), 16, 0.9),
            exec: Default::default(),
            serve: Default::default(),
            http: Default::default(),
            obs: Default::default(),
            resil: Default::default(),
            dist: Default::default(),
            artifacts_dir: "artifacts".into(),
        }
    };
    let pjrt = spion::coordinator::Trainer::new(&rt, mk_exp()).unwrap().run().unwrap();
    let mut nexp = mk_exp();
    nexp.train.lr = 0.02; // SGD needs a larger step than Adam's 1e-3
    let native = NativeTrainer::new(nexp).unwrap().run().unwrap();
    let first = |o: &spion::coordinator::TrainOutcome| o.metrics.records.first().unwrap().loss;
    let lnc = (model.classes as f32).ln();
    assert!((first(&pjrt) - lnc).abs() < 1.0, "pjrt first loss {}", first(&pjrt));
    assert!((first(&native) - lnc).abs() < 1.0, "native first loss {}", first(&native));
    let tail = |o: &spion::coordinator::TrainOutcome| {
        o.metrics.records.iter().rev().take(3).map(|r| r.loss).sum::<f32>() / 3.0
    };
    assert!(tail(&pjrt) < first(&pjrt) + 0.1, "pjrt did not optimize");
    assert!(tail(&native) < first(&native) + 0.1, "native did not optimize");
}
