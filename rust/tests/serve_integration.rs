//! Serving-layer integration + property tests (pure rust; no artifacts
//! needed): router/batcher invariants under random load, and the
//! checkpoint → encoder → server path.

use spion::model::{Encoder, ModelParams};
use spion::pattern::BlockMask;
use spion::serve::{BatchPolicy, DynamicBatcher, InferenceServer};
use spion::util::quickcheck::QuickCheck;
use spion::util::rng::Rng;
use std::sync::mpsc::channel;
use std::time::Duration;

fn random_params(rng: &mut Rng, layers: usize) -> ModelParams {
    // Mirror of the manifest layout at a small shape.
    let (vocab, l, d, ffn, classes) = (12usize, 16usize, 8usize, 32usize, 4usize);
    let mut flat: Vec<(Vec<usize>, Vec<f32>)> = Vec::new();
    let mut mat = |r: usize, c: usize, rng: &mut Rng| {
        let mut data = vec![0.0f32; r * c];
        rng.fill_normal(&mut data, 0.3);
        (vec![r, c], data)
    };
    flat.push(mat(vocab, d, rng));
    flat.push(mat(l, d, rng));
    for _ in 0..layers {
        flat.push((vec![d], vec![1.0; d]));
        flat.push((vec![d], vec![0.0; d]));
        for _ in 0..4 {
            flat.push(mat(d, d, rng));
        }
        flat.push((vec![d], vec![1.0; d]));
        flat.push((vec![d], vec![0.0; d]));
        flat.push(mat(d, ffn, rng));
        flat.push((vec![ffn], vec![0.0; ffn]));
        flat.push(mat(ffn, d, rng));
        flat.push((vec![d], vec![0.0; d]));
    }
    flat.push(mat(d, classes, rng));
    flat.push((vec![classes], vec![0.0; classes]));
    ModelParams::from_flat(&flat, layers).unwrap()
}

#[test]
fn batcher_conserves_items_property() {
    QuickCheck::new().cases(20).run("batcher conservation", |rng| {
        let n = 1 + rng.below(200);
        let max_batch = 1 + rng.below(16);
        let (tx, rx) = channel();
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
        );
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            qc_assert_len(&batch, max_batch)?;
            seen.extend(batch);
        }
        if seen != (0..n).collect::<Vec<_>>() {
            return Err(format!("items lost/reordered: {} of {n}", seen.len()));
        }
        Ok(())
    });
}

/// Property helper: batch sizes must lie in (0, max_batch].
fn qc_assert_len(batch: &[usize], max_batch: usize) -> Result<(), String> {
    if batch.is_empty() || batch.len() > max_batch {
        return Err(format!("batch size {} violates (0, {max_batch}]", batch.len()));
    }
    Ok(())
}

#[test]
fn server_end_to_end_dense_and_sparse_agree_on_full_mask() {
    let mut rng = Rng::new(3);
    let params = random_params(&mut rng, 2);
    let toks: Vec<i32> = (0..16).map(|i| (i % 12) as i32).collect();

    let dense = InferenceServer::start(Encoder::new(params.clone(), 2), BatchPolicy::default());
    let full = vec![BlockMask::full(4, 4), BlockMask::full(4, 4)];
    let sparse = InferenceServer::start(
        Encoder::new(params, 2).with_masks(full).unwrap(),
        BatchPolicy::default(),
    );
    let rd = dense.client().infer(toks.clone()).unwrap();
    let rs = sparse.client().infer(toks).unwrap();
    assert_eq!(rd.class, rs.class);
    for (a, b) in rd.logits.iter().zip(&rs.logits) {
        assert!((a - b).abs() < 1e-3, "{:?} vs {:?}", rd.logits, rs.logits);
    }
    dense.shutdown();
    sparse.shutdown();
}

#[test]
fn bad_checkpoint_masks_error_instead_of_killing_the_server() {
    // A checkpoint whose mask section disagrees with the model must surface
    // as a Result at encoder construction (the serve path propagates it),
    // not as a panic that takes down the serving process.
    let mut rng = Rng::new(11);
    let params = random_params(&mut rng, 2);
    // One mask for two layers.
    let err = Encoder::new(params.clone(), 2)
        .with_masks(vec![BlockMask::full(4, 4)])
        .expect_err("layer-count mismatch must error");
    assert!(format!("{err:#}").contains("mask count"), "{err:#}");
    // Right count, wrong sequence coverage.
    let err = Encoder::new(params, 2)
        .with_masks(vec![BlockMask::full(2, 4), BlockMask::full(2, 4)])
        .expect_err("seq-len mismatch must error");
    assert!(format!("{err:#}").contains("tokens"), "{err:#}");
}

#[test]
fn server_under_concurrent_load_serves_everything() {
    let mut rng = Rng::new(9);
    let params = random_params(&mut rng, 2);
    let mut mask = BlockMask::empty(4, 4);
    mask.set_diagonal();
    let server = InferenceServer::start(
        Encoder::new(params, 2).with_masks(vec![mask.clone(), mask]).unwrap(),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
    );
    let n_threads = 6;
    let per_thread = 25;
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t as u64);
            let mut ok = 0;
            for _ in 0..per_thread {
                let toks: Vec<i32> = (0..16).map(|_| rng.below(12) as i32).collect();
                if client.infer(toks).is_some() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, n_threads * per_thread);
    assert_eq!(
        server.stats.served.load(std::sync::atomic::Ordering::Relaxed) as usize,
        total
    );
    // Batching actually batched under concurrency.
    assert!(server.stats.mean_batch() > 1.0, "mean batch {}", server.stats.mean_batch());
    server.shutdown();
}
