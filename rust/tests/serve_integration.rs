//! Serving-layer integration + property tests (pure rust; no artifacts
//! needed): router/batcher invariants under random load, the legacy
//! `Client::infer` compatibility path, and the ticketed engine (bounded
//! admission, typed errors, per-worker kernel parallelism).

use spion::model::{Encoder, ModelParams};
use spion::pattern::BlockMask;
use spion::serve::{
    AdmissionError, BatchPolicy, DynamicBatcher, Engine, InferenceServer, ServeConfig,
};
use spion::util::quickcheck::QuickCheck;
use spion::util::rng::Rng;
use std::sync::mpsc::channel;
use std::time::Duration;

fn random_params(rng: &mut Rng, layers: usize) -> ModelParams {
    random_params_shaped(rng, layers, 12, 16, 8, 32, 4)
}

/// Mirror of the manifest layout at an arbitrary small shape (big-L
/// engine tests size L up; the legacy tests keep the historical 16).
fn random_params_shaped(
    rng: &mut Rng,
    layers: usize,
    vocab: usize,
    l: usize,
    d: usize,
    ffn: usize,
    classes: usize,
) -> ModelParams {
    let mut flat: Vec<(Vec<usize>, Vec<f32>)> = Vec::new();
    let mut mat = |r: usize, c: usize, rng: &mut Rng| {
        let mut data = vec![0.0f32; r * c];
        rng.fill_normal(&mut data, 0.3);
        (vec![r, c], data)
    };
    flat.push(mat(vocab, d, rng));
    flat.push(mat(l, d, rng));
    for _ in 0..layers {
        flat.push((vec![d], vec![1.0; d]));
        flat.push((vec![d], vec![0.0; d]));
        for _ in 0..4 {
            flat.push(mat(d, d, rng));
        }
        flat.push((vec![d], vec![1.0; d]));
        flat.push((vec![d], vec![0.0; d]));
        flat.push(mat(d, ffn, rng));
        flat.push((vec![ffn], vec![0.0; ffn]));
        flat.push(mat(ffn, d, rng));
        flat.push((vec![d], vec![0.0; d]));
    }
    flat.push(mat(d, classes, rng));
    flat.push((vec![classes], vec![0.0; classes]));
    ModelParams::from_flat(&flat, layers).unwrap()
}

#[test]
fn batcher_conserves_items_property() {
    QuickCheck::new().cases(20).run("batcher conservation", |rng| {
        let n = 1 + rng.below(200);
        let max_batch = 1 + rng.below(16);
        let (tx, rx) = channel();
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = DynamicBatcher::new(
            rx,
            BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
        );
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            qc_assert_len(&batch, max_batch)?;
            seen.extend(batch);
        }
        if seen != (0..n).collect::<Vec<_>>() {
            return Err(format!("items lost/reordered: {} of {n}", seen.len()));
        }
        Ok(())
    });
}

/// Property helper: batch sizes must lie in (0, max_batch].
fn qc_assert_len(batch: &[usize], max_batch: usize) -> Result<(), String> {
    if batch.is_empty() || batch.len() > max_batch {
        return Err(format!("batch size {} violates (0, {max_batch}]", batch.len()));
    }
    Ok(())
}

#[test]
fn server_end_to_end_dense_and_sparse_agree_on_full_mask() {
    let mut rng = Rng::new(3);
    let params = random_params(&mut rng, 2);
    let toks: Vec<i32> = (0..16).map(|i| (i % 12) as i32).collect();

    let dense = InferenceServer::start(Encoder::new(params.clone(), 2), BatchPolicy::default());
    let full = vec![BlockMask::full(4, 4), BlockMask::full(4, 4)];
    let sparse = InferenceServer::start(
        Encoder::new(params, 2).with_masks(full).unwrap(),
        BatchPolicy::default(),
    );
    let rd = dense.client().infer(toks.clone()).unwrap();
    let rs = sparse.client().infer(toks).unwrap();
    assert_eq!(rd.class, rs.class);
    for (a, b) in rd.logits.iter().zip(&rs.logits) {
        assert!((a - b).abs() < 1e-3, "{:?} vs {:?}", rd.logits, rs.logits);
    }
    dense.shutdown();
    sparse.shutdown();
}

#[test]
fn bad_checkpoint_masks_error_instead_of_killing_the_server() {
    // A checkpoint whose mask section disagrees with the model must surface
    // as a Result at encoder construction (the serve path propagates it),
    // not as a panic that takes down the serving process.
    let mut rng = Rng::new(11);
    let params = random_params(&mut rng, 2);
    // One mask for two layers.
    let err = Encoder::new(params.clone(), 2)
        .with_masks(vec![BlockMask::full(4, 4)])
        .expect_err("layer-count mismatch must error");
    assert!(format!("{err:#}").contains("mask count"), "{err:#}");
    // Right count, wrong sequence coverage.
    let err = Encoder::new(params, 2)
        .with_masks(vec![BlockMask::full(2, 4), BlockMask::full(2, 4)])
        .expect_err("seq-len mismatch must error");
    assert!(format!("{err:#}").contains("tokens"), "{err:#}");
}

#[test]
fn server_under_concurrent_load_serves_everything() {
    let mut rng = Rng::new(9);
    let params = random_params(&mut rng, 2);
    let mut mask = BlockMask::empty(4, 4);
    mask.set_diagonal();
    let server = InferenceServer::start(
        Encoder::new(params, 2).with_masks(vec![mask.clone(), mask]).unwrap(),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
    );
    let n_threads = 6;
    let per_thread = 25;
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t as u64);
            let mut ok = 0;
            for _ in 0..per_thread {
                let toks: Vec<i32> = (0..16).map(|_| rng.below(12) as i32).collect();
                if client.infer(toks).is_some() {
                    ok += 1;
                }
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, n_threads * per_thread);
    assert_eq!(
        server.stats.served.load(std::sync::atomic::Ordering::Relaxed) as usize,
        total
    );
    // Batching actually batched under concurrency.
    assert!(server.stats.mean_batch() > 1.0, "mean batch {}", server.stats.mean_batch());
    server.shutdown();
}

// ---------- ticketed engine (bounded admission, typed errors) ----------

/// A deliberately non-trivial model (L = 128) so one forward costs real
/// time: the overload tests below rely on the worker being orders of
/// magnitude slower than `try_submit`, which is lock-bound (~µs).
fn big_encoder(rng: &mut Rng, sparse: bool) -> Encoder {
    let params = random_params_shaped(rng, 2, 20, 128, 32, 64, 4);
    let enc = Encoder::new(params, 2);
    if sparse {
        let mut m = BlockMask::empty(8, 16); // 8×8 blocks of 16 → L=128
        m.set_diagonal();
        enc.with_masks(vec![m.clone(), m]).unwrap()
    } else {
        enc
    }
}

fn big_toks(rng: &mut Rng) -> Vec<i32> {
    (0..128).map(|_| rng.below(20) as i32).collect()
}

#[test]
fn try_submit_sheds_at_capacity_and_recovers_after_drain() {
    let mut rng = Rng::new(21);
    let engine = Engine::start(
        big_encoder(&mut rng, false),
        ServeConfig { queue_depth: 4, max_batch: 1, workers: 1, ..Default::default() },
    )
    .unwrap();
    // Offer far more than the queue can hold while the single worker chews
    // ~hundreds of µs per request: rejections are guaranteed.
    let mut tickets = Vec::new();
    let mut rejected = 0usize;
    for _ in 0..64 {
        match engine.try_submit(big_toks(&mut rng)) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => rejected += 1,
            Err(e) => panic!("unexpected admission error {e}"),
        }
    }
    assert!(rejected > 0, "overload must shed with QueueFull");
    let stats = engine.stats();
    assert_eq!(
        stats.rejected.load(std::sync::atomic::Ordering::Relaxed) as usize,
        rejected
    );
    // The bounded queue never grew past its capacity.
    assert!(
        stats.queue_peak.load(std::sync::atomic::Ordering::Relaxed) <= 4,
        "admission queue exceeded queue_depth"
    );
    // Every admitted ticket resolves with a response.
    for t in &tickets {
        assert!(t.wait().is_ok());
    }
    // After the drain there is room again.
    let t = engine.try_submit(big_toks(&mut rng)).expect("drained queue re-admits");
    assert!(t.wait().is_ok());
    engine.shutdown();
}

#[test]
fn wait_timeout_elapses_without_deadlock_then_resolves() {
    let mut rng = Rng::new(22);
    let engine = Engine::start(
        big_encoder(&mut rng, false),
        ServeConfig { queue_depth: 32, max_batch: 2, workers: 1, ..Default::default() },
    )
    .unwrap();
    let tickets: Vec<_> = (0..16).map(|_| engine.submit(big_toks(&mut rng)).unwrap()).collect();
    let last = tickets.last().unwrap();
    // Drive the last ticket purely through short timed waits: each call
    // must return (Some or None) rather than park forever, and the loop
    // terminates exactly when the engine resolves it — a deadlock here is
    // caught by the suite's timeout. (The deterministic "a pending ticket's
    // wait_timeout elapses" property is unit-tested in serve::ticket where
    // no worker can race the clock.)
    let resolved = loop {
        match last.wait_timeout(Duration::from_micros(200)) {
            Some(r) => break r,
            None => continue,
        }
    };
    assert!(resolved.is_ok());
    // poll() agrees with the timed wait once resolved.
    assert_eq!(last.poll().unwrap().unwrap().id, resolved.unwrap().id);
    // Full wait still resolves every ticket.
    for t in &tickets {
        assert!(t.wait().is_ok());
    }
    assert!(last.wait_timeout(Duration::ZERO).is_some(), "resolved ticket returns instantly");
    engine.shutdown();
}

#[test]
fn threads_by_tickets_all_resolve_exactly_once() {
    let mut rng = Rng::new(23);
    let engine = std::sync::Arc::new(
        Engine::start(
            big_encoder(&mut rng, true),
            ServeConfig { queue_depth: 128, max_batch: 4, workers: 2, ..Default::default() },
        )
        .unwrap(),
    );
    let n_threads = 4;
    let per_thread = 16;
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let engine = engine.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t as u64);
            let tickets: Vec<_> = (0..per_thread)
                .map(|_| engine.submit(big_toks(&mut rng)).expect("admitted"))
                .collect();
            tickets
                .into_iter()
                .map(|t| {
                    let r = t.wait().expect("resolved with a response");
                    // A resolved ticket stays resolved, with the same id.
                    assert_eq!(t.poll().unwrap().unwrap().id, r.id);
                    r.id
                })
                .collect::<Vec<u64>>()
        }));
    }
    let ids: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert_eq!(ids.len(), n_threads * per_thread);
    let unique: std::collections::HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(unique.len(), ids.len(), "each ticket resolved with its own response");
    assert_eq!(
        engine.stats().served.load(std::sync::atomic::Ordering::Relaxed) as usize,
        ids.len()
    );
    engine.shutdown();
}

#[test]
fn big_l_kernel_parallelism_bit_identical_to_serial() {
    // The per-worker exec pool (kernel_workers) parallelizes the sparse
    // kernels *inside* one request; DESIGN.md's determinism contract says
    // the logits must not depend on the worker count — bit-for-bit.
    let mut rng = Rng::new(24);
    let params = random_params_shaped(&mut rng, 2, 20, 128, 32, 64, 4);
    let mut mask = BlockMask::empty(8, 16);
    mask.set_diagonal();
    let mk = |kernel_workers: usize| {
        let enc = Encoder::new(params.clone(), 2)
            .with_masks(vec![mask.clone(), mask.clone()])
            .unwrap();
        Engine::start(
            enc,
            ServeConfig { queue_depth: 16, workers: 1, kernel_workers, ..Default::default() },
        )
        .unwrap()
    };
    let toks = big_toks(&mut rng);
    let serial = mk(1);
    let expect = serial.try_submit(toks.clone()).unwrap().wait().unwrap();
    serial.shutdown();
    let parallel = mk(4);
    let got = parallel.try_submit(toks).unwrap().wait().unwrap();
    parallel.shutdown();
    assert_eq!(expect.class, got.class);
    assert_eq!(expect.logits.len(), got.logits.len());
    for (a, b) in expect.logits.iter().zip(&got.logits) {
        assert_eq!(a.to_bits(), b.to_bits(), "kernel_workers changed the numerics");
    }
}

#[test]
fn preemption_resolves_a_blocked_wait_timeout_exactly_once() {
    // Regression guard for the EDF shed path: a ticket evicted from the
    // admission queue by a higher class must wake a client already parked
    // in `wait_timeout` with the typed `Preempted` error — exactly once,
    // not a timeout, not a hang, not a double resolve.
    use spion::serve::{Class, ServeError};
    let mut rng = Rng::new(26);
    let engine = Engine::start(
        big_encoder(&mut rng, false),
        ServeConfig { queue_depth: 2, max_batch: 1, workers: 1, ..Default::default() },
    )
    .unwrap();
    // Occupy the single worker, and wait for the pop so the queue is
    // empty and stable: one dense L=128 forward is orders of magnitude
    // longer than the submissions below.
    let busy = engine.try_submit(big_toks(&mut rng)).unwrap();
    while engine.queue_len_class(Class::Interactive) > 0 {
        std::thread::yield_now();
    }
    // Two best-effort requests fill the queue; `victim` (lower seq) is
    // evicted second, after `filler`.
    let victim = engine.try_submit_classed(big_toks(&mut rng), Class::BestEffort, None).unwrap();
    let filler = engine.try_submit_classed(big_toks(&mut rng), Class::BestEffort, None).unwrap();
    // Park a client in a long timed wait on the victim before the
    // preemption fires.
    let waiter = std::thread::spawn(move || {
        let first = victim.wait_timeout(Duration::from_secs(30));
        // A resolved ticket stays resolved with the same outcome.
        let again = victim.wait_timeout(Duration::ZERO);
        (first, again)
    });
    for _ in 0..64 {
        std::thread::yield_now(); // let the waiter actually park
    }
    // Interactive arrivals displace the queued best-effort entries
    // (worst key first: filler, then victim).
    let hi: Vec<_> = (0..2)
        .map(|_| engine.try_submit_classed(big_toks(&mut rng), Class::Interactive, None).unwrap())
        .collect();
    let (first, again) = waiter.join().unwrap();
    match first {
        Some(Err(ServeError::Preempted)) => {}
        other => panic!("victim must resolve Preempted, got {other:?}"),
    }
    match again {
        Some(Err(ServeError::Preempted)) => {}
        other => panic!("second wait must repeat the same resolution, got {other:?}"),
    }
    match filler.wait() {
        Err(ServeError::Preempted) => {}
        other => panic!("filler must resolve Preempted, got {other:?}"),
    }
    assert!(busy.wait().is_ok());
    for t in hi {
        assert!(t.wait().is_ok(), "displacing requests are served");
    }
    let stats = engine.stats();
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(stats.preempted.load(Relaxed), 2);
    assert_eq!(stats.class_preempted[Class::BestEffort.index()].load(Relaxed), 2);
    assert_eq!(stats.class_preempted[Class::Interactive.index()].load(Relaxed), 0);
    engine.shutdown();
    // Conservation: admitted = served + preempted, every ticket exactly once.
    assert_eq!(stats.admitted.load(Relaxed), 5);
    assert_eq!(stats.served.load(Relaxed), 3);
}

#[test]
fn bad_requests_are_typed_and_do_not_kill_workers() {
    let mut rng = Rng::new(25);
    let engine = Engine::start(big_encoder(&mut rng, false), ServeConfig::default()).unwrap();
    match engine.try_submit(vec![0; 7]) {
        Err(AdmissionError::BadRequest { reason }) => assert!(reason.contains("128"), "{reason}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
    match engine.try_submit(vec![999; 128]) {
        Err(AdmissionError::BadRequest { reason }) => assert!(reason.contains("vocab"), "{reason}"),
        other => panic!("expected BadRequest, got {other:?}"),
    }
    // The engine keeps serving — no worker was poisoned by the bad input.
    assert!(engine.try_submit(big_toks(&mut rng)).unwrap().wait().is_ok());
    engine.shutdown();
}
