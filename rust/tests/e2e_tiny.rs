//! Integration tests over the AOT artifacts + PJRT runtime (tiny preset).
//! Skipped with a notice when artifacts are missing (`make artifacts`).
//!
//! These are the compose-proof tests: python-lowered HLO executed from
//! rust, three-phase training, and rust-native-engine ↔ XLA parity.

use spion::config::types::{preset, SparsityConfig};
use spion::config::{ExperimentConfig, PatternKind, TrainConfig};
use spion::coordinator::Trainer;
use spion::metrics::Phase;
use spion::model::{Encoder, ModelParams};
use spion::pattern::SpionVariant;
use spion::runtime::executor::lit;
use spion::runtime::{ArtifactSet, Runtime};

fn artifacts_available() -> bool {
    let ok = std::path::Path::new("artifacts/tiny/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/tiny missing — run `make artifacts`");
    }
    ok
}

fn tiny_exp(kind: PatternKind, steps: usize) -> ExperimentConfig {
    let (task, model) = preset("tiny").unwrap();
    let train = TrainConfig {
        steps,
        min_dense_steps: 6,
        max_dense_steps: 12,
        snapshot_every: 3,
        ..Default::default()
    };
    ExperimentConfig {
        task,
        model,
        train,
        sparsity: SparsityConfig::new(kind, 16, 0.9),
        exec: Default::default(),
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        dist: Default::default(),
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn three_phase_training_reduces_loss_and_generates_patterns() {
    if !artifacts_available() {
        return;
    }
    std::env::set_var("SPION_EVAL_BATCHES", "2");
    let rt = Runtime::cpu().unwrap();
    let exp = tiny_exp(PatternKind::Spion(SpionVariant::CF), 30);
    let outcome = Trainer::new(&rt, exp).unwrap().run().unwrap();
    let m = &outcome.metrics;

    // Phase structure (Fig. 2): dense prefix, sparse suffix, one transition.
    let t = m.transition_step.expect("transition fired");
    assert!(t >= 6 && t <= 12, "transition at {t}");
    assert!(m.records.iter().take(t).all(|r| r.phase == Phase::Dense));
    assert!(m.records.iter().skip(t + 1).all(|r| r.phase == Phase::Sparse));

    // Patterns: per layer, block-sparse, diagonal present.
    let masks = outcome.masks.as_ref().expect("masks generated");
    assert_eq!(masks.len(), 2);
    for mask in masks {
        assert!(mask.density() < 0.5, "density {}", mask.density());
        for k in 0..mask.lb {
            assert!(mask.get(k, k), "diagonal block {k}");
        }
    }

    // Optimization signal: loss at end below loss at start.
    let first = m.records.first().unwrap().loss;
    let last_avg: f32 =
        m.records.iter().rev().take(5).map(|r| r.loss).sum::<f32>() / 5.0;
    assert!(last_avg < first, "loss did not decrease: {first} → {last_avg}");
    assert!(m.eval_accuracy.unwrap() >= 0.0);
}

#[test]
fn dense_baseline_never_transitions() {
    if !artifacts_available() {
        return;
    }
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let rt = Runtime::cpu().unwrap();
    let exp = tiny_exp(PatternKind::Dense, 16);
    let outcome = Trainer::new(&rt, exp).unwrap().run().unwrap();
    assert!(outcome.metrics.transition_step.is_none());
    assert!(outcome.masks.is_none());
    assert!(outcome.metrics.records.iter().all(|r| r.phase == Phase::Dense));
}

#[test]
fn all_baseline_kinds_train() {
    if !artifacts_available() {
        return;
    }
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let rt = Runtime::cpu().unwrap();
    for kind in [
        PatternKind::BigBird,
        PatternKind::Reformer,
        PatternKind::Spion(SpionVariant::C),
        PatternKind::Spion(SpionVariant::F),
    ] {
        let exp = tiny_exp(kind, 14);
        let outcome = Trainer::new(&rt, exp).unwrap().run().unwrap();
        assert!(
            outcome.metrics.transition_step.is_some(),
            "{} did not transition",
            kind.name()
        );
        assert!(outcome.metrics.final_loss().unwrap().is_finite(), "{}", kind.name());
    }
}

#[test]
fn rust_native_encoder_matches_xla_dense_fwd() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let artifacts = ArtifactSet::open("artifacts", "tiny").unwrap();
    let m = &artifacts.manifest;
    let init = rt.load(&artifacts.path("init")).unwrap();
    let dense_fwd = rt.load(&artifacts.path("dense_fwd")).unwrap();

    let params = init.run(&[lit::scalar_u32(3)]).unwrap();
    // Batch through XLA.
    let (task, model) = preset("tiny").unwrap();
    let gen = spion::data::make_task(task, m.seq_len, m.vocab, m.classes);
    let mut batcher = spion::data::batcher::Batcher::new(gen, m.batch, 5);
    let batch = batcher.next_batch();
    let mut inputs = params.clone();
    inputs.push(lit::i32_vec(&batch.x, &[m.batch as i64, m.seq_len as i64]).unwrap());
    let xla_logits = lit::to_f32_vec(&dense_fwd.run(&inputs).unwrap()[0]).unwrap();

    // Same batch through the rust-native engine.
    let flat: Vec<(Vec<usize>, Vec<f32>)> = params
        .iter()
        .zip(&m.params)
        .map(|(l, spec)| (spec.shape.clone(), lit::to_f32_vec(l).unwrap()))
        .collect();
    let mut enc = Encoder::new(ModelParams::from_flat(&flat, m.layers).unwrap(), model.heads);
    let native = enc.forward_batch(&batch.x, m.batch);

    // Parity: same argmax everywhere, logits close.
    for b in 0..m.batch {
        let xrow = &xla_logits[b * m.classes..(b + 1) * m.classes];
        let nrow = native.row(b);
        let xa = spion::tensor::ops::argmax(xrow);
        let na = spion::tensor::ops::argmax(nrow);
        assert_eq!(xa, na, "batch {b}: argmax differs: {xrow:?} vs {nrow:?}");
        for (x, n) in xrow.iter().zip(nrow) {
            assert!((x - n).abs() < 2e-2 + 0.05 * x.abs(), "batch {b}: {xrow:?} vs {nrow:?}");
        }
    }
}

#[test]
fn manifest_matches_rust_presets() {
    // For every built preset, the python-emitted manifest must agree with
    // the rust preset table (ABI drift check).
    let mut checked = 0;
    for (_, model) in spion::config::types::presets() {
        let path = format!("artifacts/{}/manifest.json", model.preset);
        if !std::path::Path::new(&path).exists() {
            continue;
        }
        let m = spion::runtime::Manifest::load(&path).unwrap();
        m.check_against(&model).unwrap_or_else(|e| panic!("{e}"));
        checked += 1;
    }
    if checked == 0 {
        eprintln!("SKIP: no artifacts built");
    }
}

#[test]
fn checkpoint_roundtrip_through_encoder() {
    if !artifacts_available() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let exp = tiny_exp(PatternKind::Spion(SpionVariant::CF), 10);
    let trainer = Trainer::new(&rt, exp).unwrap();
    let outcome = trainer.run().unwrap();
    let path = std::env::temp_dir().join("spion_e2e_ck.bin");
    let path = path.to_str().unwrap();
    trainer.save_checkpoint(&outcome, path).unwrap();
    let ck = spion::coordinator::checkpoint::Checkpoint::load(path).unwrap();
    assert_eq!(ck.preset, "tiny");
    let params = ModelParams::from_checkpoint(&ck, 2).unwrap();
    let mut enc = Encoder::new(params, 2);
    let toks: Vec<i32> = (0..128).map(|i| (i % 17) as i32).collect();
    let logits = enc.forward(&toks);
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
    std::fs::remove_file(path).ok();
}
