//! Fused-backward parity + the zero-allocation sparse training phase —
//! the determinism and steady-state-memory contract of
//! `sparse::kernel::fused_bwd` and the native trainer's free-lists
//! (DESIGN.md §Fused backward & overlapped reduction):
//!
//! * **fused-bwd scalar ↔ unfused**: bit-for-bit across the pattern zoo
//!   (SPION-C/F/CF, BigBird, Reformer/LSH) × block sizes {2, 4, 8} ×
//!   workers {1, 2, 4} — with `simd` off the two-sweep backward keeps the
//!   five-pass kernels' exact association;
//! * **fused-bwd SIMD ↔ unfused**: allclose (the 8-lane SDDMM dot and
//!   Jacobian rowsum reassociate);
//! * **fused-bwd serial ↔ parallel**: bit-for-bit at any worker count;
//! * finite-difference gradient checks **through the fused path**;
//! * the native trainer's **overlapped ordered fold**: whole-trajectory
//!   bit-identity at workers {1, 2, 4}, and fused-bwd-scalar ≡
//!   unfused-scalar trajectories bit-for-bit;
//! * an **allocation-count regression**: a counting global allocator
//!   witnesses that the warm sparse attention fwd+bwd performs zero heap
//!   allocations, and that `train_step_sample` with a pooled `TrainCache`
//!   has a stable (and strictly smaller) per-call allocation count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use spion::attention::{sparse_attention_train_with, TrainWorkspace};
use spion::config::types::SparsityConfig;
use spion::config::{ExperimentConfig, ModelConfig, PatternKind, TaskKind, TrainConfig};
use spion::coordinator::NativeTrainer;
use spion::exec::{Exec, ExecConfig, KernelConfig};
use spion::model::grad::ModelGrads;
use spion::model::{train_step_sample, ModelParams, TrainCache};
use spion::pattern::bigbird::bigbird;
use spion::pattern::lsh::lsh_pattern;
use spion::pattern::spion::{generate_pattern, synth_attention_scores, PatternConfig};
use spion::pattern::{BlockMask, SpionVariant};
use spion::tensor::Mat;
use spion::util::quickcheck::{assert_allclose, QuickCheck};
use spion::util::rng::Rng;

// ---- counting allocator ------------------------------------------------

thread_local! {
    /// Allocations made by *this* thread (const-init Cell: reading/writing
    /// it never allocates, so the allocator cannot recurse). Thread-local
    /// so concurrently-running tests in this binary cannot pollute each
    /// other's counts — the witnessed paths all run on a serial exec,
    /// i.e. on the measuring thread itself.
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers to the system allocator; the bookkeeping is a const-init
// thread-local Cell bump, which performs no allocation.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        THREAD_ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

// ---- shared fixtures ---------------------------------------------------

const FB_SIMD: KernelConfig = KernelConfig { fused: true, simd: true, fused_bwd: true };
/// Unfused forward + fused scalar backward: isolates the backward routing,
/// so any bit difference against UNFUSED is the fused backward's fault.
const FB_SCALAR: KernelConfig = KernelConfig { fused: false, simd: false, fused_bwd: true };
const UNFUSED: KernelConfig = KernelConfig { fused: false, simd: false, fused_bwd: false };

fn exec_with(workers: usize, kernel: KernelConfig) -> Exec {
    Exec::new(ExecConfig { workers, kernel, ..Default::default() })
}

/// A pattern from every policy the engine supports, at block size `block`.
fn pattern_zoo(rng: &mut Rng, l: usize, block: usize) -> Vec<(String, BlockMask)> {
    let scores = synth_attention_scores(l, 0.8, 0.4, &[l / 3], 0.05, rng);
    let lb = l / block;
    let mut zoo = Vec::new();
    for variant in [SpionVariant::C, SpionVariant::F, SpionVariant::CF] {
        let cfg = PatternConfig { variant, block, filter: 5, alpha: 0.5 + 0.45 * rng.f64() };
        zoo.push((variant.name().to_string(), generate_pattern(&scores, &cfg)));
    }
    zoo.push(("BigBird".into(), bigbird(lb, block, &Default::default(), rng)));
    zoo.push(("Reformer".into(), lsh_pattern(&scores, block, &Default::default(), rng)));
    zoo
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

/// Run the full fwd+bwd train pass under `exec` and return the workspace.
fn train(
    exec: &Exec,
    mask: &BlockMask,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    cot: &Mat,
    scale: f32,
) -> TrainWorkspace {
    let mut ws = TrainWorkspace::new(mask, q.cols);
    sparse_attention_train_with(exec, q, k, v, scale, cot, &mut ws);
    ws
}

// ---- backward parity ---------------------------------------------------

#[test]
fn fused_bwd_scalar_bitwise_equals_unfused_over_zoo() {
    QuickCheck::new().cases(10).run("fused bwd scalar = unfused", |rng| {
        let block = [2usize, 4, 8][rng.below(3)];
        let lb = (16 / block).max(2) + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(10);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.9, rng);
        let k = Mat::random_normal(l, d, 0.9, rng);
        let v = Mat::random_normal(l, d, 0.9, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let ws_ref = train(&exec_with(1, UNFUSED), &mask, &q, &k, &v, &cot, scale);
            for workers in [1usize, 2, 4] {
                let ws = train(&exec_with(workers, FB_SCALAR), &mask, &q, &k, &v, &cot, scale);
                let tag = format!("{name} B={block} w={workers}");
                assert_bits_eq(&ws.dq.data, &ws_ref.dq.data, &format!("dQ {tag}"));
                assert_bits_eq(&ws.dk.data, &ws_ref.dk.data, &format!("dK {tag}"));
                assert_bits_eq(&ws.dv.data, &ws_ref.dv.data, &format!("dV {tag}"));
            }
        }
        Ok(())
    });
}

#[test]
fn fused_bwd_simd_allclose_to_unfused_over_zoo() {
    QuickCheck::new().cases(10).run("fused bwd simd ≈ unfused", |rng| {
        let block = [2usize, 4, 8][rng.below(3)];
        let lb = (16 / block).max(2) + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(12);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.9, rng);
        let k = Mat::random_normal(l, d, 0.9, rng);
        let v = Mat::random_normal(l, d, 0.9, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let ws_ref = train(&exec_with(1, UNFUSED), &mask, &q, &k, &v, &cot, scale);
            for workers in [1usize, 2, 4] {
                let ws = train(&exec_with(workers, FB_SIMD), &mask, &q, &k, &v, &cot, scale);
                for (what, got, want) in [
                    ("dq", &ws.dq.data, &ws_ref.dq.data),
                    ("dk", &ws.dk.data, &ws_ref.dk.data),
                    ("dv", &ws.dv.data, &ws_ref.dv.data),
                ] {
                    assert_allclose(got, want, 1e-3, 1e-5).unwrap_or_else(|e| {
                        panic!("{name} B={block} {what} w={workers}: {e}")
                    });
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fused_bwd_serial_parallel_bit_identical_over_zoo() {
    QuickCheck::new().cases(8).run("fused bwd serial↔parallel", |rng| {
        let block = [4usize, 8][rng.below(2)];
        let lb = (16 / block).max(2) + rng.below(4);
        let l = lb * block;
        let d = 2 + rng.below(10);
        let scale = 1.0 / (d as f32).sqrt();
        let q = Mat::random_normal(l, d, 0.9, rng);
        let k = Mat::random_normal(l, d, 0.9, rng);
        let v = Mat::random_normal(l, d, 0.9, rng);
        let cot = Mat::random_normal(l, d, 1.0, rng);

        for (name, mask) in pattern_zoo(rng, l, block) {
            let ws_ref = train(&exec_with(1, FB_SIMD), &mask, &q, &k, &v, &cot, scale);
            for workers in [2usize, 4] {
                let ws = train(&exec_with(workers, FB_SIMD), &mask, &q, &k, &v, &cot, scale);
                let tag = format!("{name} w={workers}");
                assert_bits_eq(&ws.dq.data, &ws_ref.dq.data, &format!("dQ {tag}"));
                assert_bits_eq(&ws.dk.data, &ws_ref.dk.data, &format!("dK {tag}"));
                assert_bits_eq(&ws.dv.data, &ws_ref.dv.data, &format!("dV {tag}"));
            }
        }
        Ok(())
    });
}

#[test]
fn finite_differences_pass_through_fused_backward() {
    // Scalar loss L = Σ (O ⊙ C): central differences through the (fused)
    // forward vs the fused backward's analytic gradients.
    let mut rng = Rng::new(11);
    let (lb, block, dh) = (3, 4, 6);
    let l = lb * block;
    let mut mask = BlockMask::empty(lb, block);
    for bit in mask.bits.iter_mut() {
        *bit = rng.chance(0.5);
    }
    mask.set_diagonal();
    let q = Mat::random_normal(l, dh, 0.7, &mut rng);
    let k = Mat::random_normal(l, dh, 0.7, &mut rng);
    let v = Mat::random_normal(l, dh, 0.7, &mut rng);
    let cot = Mat::random_normal(l, dh, 1.0, &mut rng);
    let scale = 1.0 / (dh as f32).sqrt();
    for kernel in [FB_SIMD, FB_SCALAR] {
        let exec = exec_with(1, kernel);
        let ws = train(&exec, &mask, &q, &k, &v, &cot, scale);
        let loss = |q: &Mat, k: &Mat, v: &Mat| -> f64 {
            let mut w = TrainWorkspace::new(&mask, dh);
            sparse_attention_train_with(&exec, q, k, v, scale, &cot, &mut w);
            w.fwd.ctx.data.iter().zip(&cot.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let eps = 1e-3f32;
        for (which, grad) in [(0usize, &ws.dq), (1, &ws.dk), (2, &ws.dv)] {
            let mut worst = 0.0f64;
            for idx in 0..l * dh {
                let (mut qp, mut kp, mut vp) = (q.clone(), k.clone(), v.clone());
                let (mut qm, mut km, mut vm) = (q.clone(), k.clone(), v.clone());
                let (tp, tm) = match which {
                    0 => (&mut qp.data[idx], &mut qm.data[idx]),
                    1 => (&mut kp.data[idx], &mut km.data[idx]),
                    _ => (&mut vp.data[idx], &mut vm.data[idx]),
                };
                *tp += eps;
                *tm -= eps;
                let fd = (loss(&qp, &kp, &vp) - loss(&qm, &km, &vm)) / (2.0 * eps as f64);
                let an = grad.data[idx] as f64;
                let err = (fd - an).abs() / (1e-3 + fd.abs().max(an.abs()));
                worst = worst.max(err);
            }
            assert!(worst < 0.05, "tensor {which} fd mismatch (worst rel {worst}) {kernel:?}");
        }
    }
}

// ---- native-trainer trajectory ----------------------------------------

fn micro_exp(workers: usize, kernel: KernelConfig) -> ExperimentConfig {
    let model = ModelConfig {
        preset: "micro".into(),
        seq_len: 32,
        d_model: 16,
        heads: 2,
        layers: 2,
        ffn_dim: 32,
        vocab: 20,
        classes: 10,
        batch: 4,
    };
    let train = TrainConfig {
        steps: 10,
        lr: 0.02,
        min_dense_steps: 4,
        max_dense_steps: 8,
        snapshot_every: 2,
        ..Default::default()
    };
    let mut sparsity = SparsityConfig::new(PatternKind::Spion(SpionVariant::CF), 8, 0.7);
    sparsity.pattern.filter = 3;
    ExperimentConfig {
        task: TaskKind::ListOps,
        model,
        train,
        sparsity,
        exec: ExecConfig { workers, kernel, ..Default::default() },
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        dist: Default::default(),
        artifacts_dir: "artifacts".into(),
    }
}

#[test]
fn overlapped_fold_trajectory_bit_identical_at_any_worker_count() {
    // The overlapped ordered fold must keep the whole training trajectory
    // (losses, masks, final parameters) bit-identical from 1 to N workers,
    // with the fused backward on (the default kernel config).
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let run = |workers: usize| {
        NativeTrainer::new(micro_exp(workers, KernelConfig::default())).unwrap().run().unwrap()
    };
    let serial = run(1);
    for workers in [2usize, 4] {
        let parallel = run(workers);
        assert_eq!(serial.metrics.records.len(), parallel.metrics.records.len());
        for (a, b) in serial.metrics.records.iter().zip(&parallel.metrics.records) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {} w={workers}", a.step);
        }
        assert_eq!(serial.masks, parallel.masks, "w={workers}");
        for (a, b) in serial.final_params.iter().zip(&parallel.final_params) {
            assert_eq!(a, b, "final params w={workers}");
        }
    }
}

#[test]
fn fused_bwd_scalar_trajectory_bitwise_equals_unfused() {
    // Whole-trainer tier of the scalar contract: swapping only the
    // backward pipeline (five-pass → fused two-sweep, both scalar) must
    // not move a single bit of the training trajectory.
    std::env::set_var("SPION_EVAL_BATCHES", "1");
    let run = |kernel: KernelConfig| {
        NativeTrainer::new(micro_exp(2, kernel)).unwrap().run().unwrap()
    };
    let fused = run(FB_SCALAR);
    let unfused = run(UNFUSED);
    for (a, b) in fused.metrics.records.iter().zip(&unfused.metrics.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}", a.step);
    }
    assert_eq!(fused.masks, unfused.masks);
    for (a, b) in fused.final_params.iter().zip(&unfused.final_params) {
        assert_eq!(a, b);
    }
}

// ---- allocation regression ---------------------------------------------

#[test]
fn warm_sparse_train_pass_is_allocation_free() {
    // One fwd+bwd over a reused TrainWorkspace on a serial exec: after the
    // warmup call (arena growth, ColIndex builds), the steady-state pass
    // must perform ZERO heap allocations — this is the per-sample inner
    // loop of the sparse training phase.
    let mut rng = Rng::new(3);
    let (lb, block, d) = (6, 8, 16);
    let l = lb * block;
    let scale = 1.0 / (d as f32).sqrt();
    let q = Mat::random_normal(l, d, 0.9, &mut rng);
    let k = Mat::random_normal(l, d, 0.9, &mut rng);
    let v = Mat::random_normal(l, d, 0.9, &mut rng);
    let cot = Mat::random_normal(l, d, 1.0, &mut rng);
    let (_, mask) = pattern_zoo(&mut rng, l, block).remove(2); // SPION-CF
    for kernel in [FB_SIMD, FB_SCALAR, UNFUSED] {
        let exec = exec_with(1, kernel);
        let mut ws = TrainWorkspace::new(&mask, d);
        // Warmup: grows the thread arena to its high-water mark and builds
        // the cached column indices.
        sparse_attention_train_with(&exec, &q, &k, &v, scale, &cot, &mut ws);
        let before = thread_allocs();
        for _ in 0..3 {
            sparse_attention_train_with(&exec, &q, &k, &v, scale, &cot, &mut ws);
        }
        let after = thread_allocs();
        assert_eq!(
            after - before,
            0,
            "sparse fwd+bwd allocated {} times in steady state ({kernel:?})",
            after - before
        );
    }
}

#[test]
fn pooled_train_cache_makes_sample_allocations_stable_and_smaller() {
    // Full-encoder sample pass: with a warmed step-spanning TrainCache the
    // per-call allocation count is *constant* (the dense encoder mats are a
    // deterministic per-call sequence; the sparse phase adds nothing), and
    // strictly smaller than the cacheless call that must build fresh
    // workspaces per layer per head.
    let model = ModelConfig {
        preset: "micro".into(),
        seq_len: 16,
        d_model: 8,
        heads: 2,
        layers: 2,
        ffn_dim: 16,
        vocab: 12,
        classes: 4,
        batch: 1,
    };
    let params = ModelParams::init_random(&model, 7);
    let mut rng = Rng::new(21);
    let toks: Vec<i32> = (0..model.seq_len).map(|_| rng.below(model.vocab) as i32).collect();
    let mut m0 = BlockMask::empty(4, 4);
    m0.set_diagonal();
    m0.set(0, 2, true);
    let mut m1 = BlockMask::empty(4, 4);
    m1.set_diagonal();
    m1.set(3, 1, true);
    let masks = vec![m0, m1];
    let dh = model.d_model / model.heads;
    let exec = Exec::serial();
    let mut grads = ModelGrads::zeros_like(&params);

    let count_call = |grads: &mut ModelGrads, cache: Option<&mut TrainCache>| -> u64 {
        let before = thread_allocs();
        train_step_sample(
            &exec,
            &params,
            model.heads,
            Some(&masks),
            &toks,
            1,
            false,
            grads,
            cache,
        );
        thread_allocs() - before
    };

    let mut cache = TrainCache::new(&masks, model.heads, dh);
    let _warm = count_call(&mut grads, Some(&mut cache)); // builds ColIndex caches
    let a2 = count_call(&mut grads, Some(&mut cache));
    let a3 = count_call(&mut grads, Some(&mut cache));
    let fresh = count_call(&mut grads, None);
    assert_eq!(a2, a3, "warm per-call allocation count must be stable");
    assert!(
        fresh > a2,
        "cacheless call ({fresh} allocs) must exceed the pooled-cache call ({a2})"
    );
}
