//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The SPION runtime layer (`spion::runtime`) is written against the xla-rs
//! surface: PJRT client + compiled executables + `Literal` host buffers.
//! This vendored stand-in keeps the whole crate compiling and testable on
//! machines without the XLA shared library:
//!
//! * [`Literal`] is fully functional host-side (typed buffers, reshape,
//!   tuples) — everything marshaling code and its tests need.
//! * [`PjRtClient::cpu`] returns an error: execution paths gate on built
//!   artifacts and skip cleanly when the backend is absent.
//!
//! Linking the real backend is a one-line swap in `rust/Cargo.toml`
//! (point the `xla` dependency at xla-rs instead of `vendor/xla`); the API
//! subset here mirrors xla-rs signatures for that reason.

use std::fmt;

/// Error type for every fallible stub operation.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend not available in this build (vendored stub; \
         link the real xla-rs crate to enable runtime execution)"
    ))
}

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    I32,
    I64,
    U32,
    U8,
}

/// Internal typed storage (public only because [`NativeType`] mentions it).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Buffer {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U32(Vec<u32>),
    U8(Vec<u8>),
}

impl Buffer {
    fn len(&self) -> usize {
        match self {
            Buffer::F32(v) => v.len(),
            Buffer::F64(v) => v.len(),
            Buffer::I32(v) => v.len(),
            Buffer::I64(v) => v.len(),
            Buffer::U32(v) => v.len(),
            Buffer::U8(v) => v.len(),
        }
    }

    fn element_type(&self) -> ElementType {
        match self {
            Buffer::F32(_) => ElementType::F32,
            Buffer::F64(_) => ElementType::F64,
            Buffer::I32(_) => ElementType::I32,
            Buffer::I64(_) => ElementType::I64,
            Buffer::U32(_) => ElementType::U32,
            Buffer::U8(_) => ElementType::U8,
        }
    }
}

/// Sealed-ish conversion trait between rust scalars and literal buffers.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn buffer_from(data: &[Self]) -> Buffer;
    fn vec_from(buf: &Buffer) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            const ELEMENT_TYPE: ElementType = ElementType::$variant;
            fn buffer_from(data: &[Self]) -> Buffer {
                Buffer::$variant(data.to_vec())
            }
            fn vec_from(buf: &Buffer) -> Option<Vec<Self>> {
                match buf {
                    Buffer::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(f64, F64);
native!(i32, I32);
native!(i64, I64);
native!(u32, U32);
native!(u8, U8);

/// Host-side literal: a typed dense buffer with dimensions, or a tuple of
/// literals (executables return a single tuple literal).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    repr: Repr,
}

#[derive(Debug, Clone, PartialEq)]
enum Repr {
    Dense { buf: Buffer, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { repr: Repr::Dense { buf: T::buffer_from(data), dims: vec![data.len() as i64] } }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { repr: Repr::Dense { buf: T::buffer_from(&[v]), dims: vec![] } }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { repr: Repr::Tuple(elems) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.repr {
            Repr::Tuple(_) => Err(Error("reshape on tuple literal".into())),
            Repr::Dense { buf, .. } => {
                let count: i64 = dims.iter().product();
                if count < 0 || count as usize != buf.len() {
                    return Err(Error(format!(
                        "reshape {:?} incompatible with {} elements",
                        dims,
                        buf.len()
                    )));
                }
                Ok(Literal { repr: Repr::Dense { buf: buf.clone(), dims: dims.to_vec() } })
            }
        }
    }

    pub fn element_type(&self) -> Result<ElementType> {
        match &self.repr {
            Repr::Dense { buf, .. } => Ok(buf.element_type()),
            Repr::Tuple(_) => Err(Error("tuple literal has no element type".into())),
        }
    }

    pub fn dims(&self) -> Result<Vec<i64>> {
        match &self.repr {
            Repr::Dense { dims, .. } => Ok(dims.clone()),
            Repr::Tuple(_) => Err(Error("tuple literal has no dims".into())),
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.repr {
            Repr::Dense { buf, .. } => buf.len(),
            Repr::Tuple(t) => t.len(),
        }
    }

    /// Copy out as a flat vector of `T` (type must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.repr {
            Repr::Tuple(_) => Err(Error("to_vec on tuple literal".into())),
            Repr::Dense { buf, .. } => T::vec_from(buf).ok_or_else(|| {
                Error(format!(
                    "literal holds {:?}, requested {:?}",
                    buf.element_type(),
                    T::ELEMENT_TYPE
                ))
            }),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.repr {
            Repr::Tuple(t) => Ok(t),
            Repr::Dense { .. } => Err(Error("to_tuple on non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module (stub: retains only the source path for diagnostics).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        // Surface missing files as such; otherwise defer to compile time,
        // where the stub reports the backend as unavailable.
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("{path}: no such file")));
        }
        Err(unavailable(&format!("parsing HLO text {path}")))
    }

    pub fn path(&self) -> &str {
        &self.path
    }
}

/// Computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { path: proto.path.clone() }
    }
}

/// Device-resident buffer (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching buffer"))
    }
}

/// Loaded executable (stub: never constructed, all paths error).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }

    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing (buffers)"))
    }
}

/// PJRT client (stub: construction reports the backend as unavailable).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable(&format!("compiling {}", comp.path)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims().unwrap(), vec![2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err(), "type mismatch detected");
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.dims().unwrap(), Vec::<i64>::new());
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![7]);
        assert!(s.clone().to_tuple().is_err());
    }

    #[test]
    fn reshape_validates_count() {
        let l = Literal::vec1(&[1u32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn backend_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"), "{e}");
        assert!(HloModuleProto::from_text_file("/definitely/missing.hlo.txt").is_err());
    }
}
