//! Offline stand-in for the `anyhow` crate.
//!
//! The build is fully vendored (no network registry), so this crate provides
//! the API subset the SPION tree actually uses: [`Error`] (a context-chained
//! dynamic error), the [`anyhow!`] / [`bail!`] macros, the [`Result`] alias
//! with a defaulted error type, and the [`Context`] extension trait for
//! `Result<T, E: std::error::Error>`.
//!
//! Formatting matches the upstream conventions the callers rely on:
//! `{}` prints the outermost context, `{:#}` prints the whole chain
//! separated by `": "`, and `{:?}` prints the chain in the multi-line
//! `Caused by:` style.

use std::fmt;

/// A dynamic error: a stack of context messages, outermost first. The last
/// entry is the root cause.
pub struct Error {
    stack: Vec<String>,
}

impl Error {
    /// Build from a single displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Self { stack: vec![message.to_string()] }
    }

    /// Wrap with an additional (outermost) context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.stack.insert(0, context.to_string());
        self
    }

    /// Capture a `std::error::Error`, preserving its `source()` chain as
    /// context entries.
    pub fn from_std(err: impl std::error::Error) -> Self {
        let mut stack = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            stack.push(s.to_string());
            src = s.source();
        }
        Self { stack }
    }

    /// The root-cause message (innermost entry).
    pub fn root_cause(&self) -> &str {
        self.stack.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain on one line.
            write!(f, "{}", self.stack.join(": "))
        } else {
            write!(f, "{}", self.stack.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.stack.first().map(String::as_str).unwrap_or(""))?;
        if self.stack.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.stack[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error` — like
// upstream anyhow, this keeps the blanket `From<E: std::error::Error>` impl
// below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::from_std(err)
    }
}

/// `anyhow::Result<T>` — the error type defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// whose error is a standard error type.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from_std(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from_std(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err()).context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        let full = format!("{e:#}");
        assert!(full.starts_with("opening config: "), "{full}");
        assert!(full.contains("missing thing"), "{full}");
    }

    #[test]
    fn debug_shows_causes() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("root"), "{d}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.root_cause(), "missing thing");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
        let from_string = anyhow!(String::from("plain message"));
        assert_eq!(format!("{from_string}"), "plain message");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
    }
}
