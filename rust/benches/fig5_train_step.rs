//! Fig. 5 regenerator: per-step training time, attention memory footprint,
//! and per-request inference time for the six compared models on the three
//! tasks — with a **workers axis**: the sparse models are re-measured at
//! each `exec` worker count, recording the parallel runtime's scaling curve
//! for the full fwd+bwd step (the dense baseline is single-threaded).
//!
//! What is measured where (DESIGN.md §2): the *attention core* — the only
//! part that differs between models — runs on the rust block-CSR engine.
//! "Train step" = real forward + real backward (`sparse::backward`, same
//! block structure as the forward, finite-difference-validated). Dense rows
//! are the Original-Transformer baseline. Memory is the score-matrix
//! working set (`metrics::attention_bytes_*`).
//!
//! Paper reference: SPION-CF 1.66× / 2.21× / 3.08× step speedup and 4.62× /
//! 7.23× / 9.64× memory reduction on image / listops / retrieval.
//!
//! Run: cargo bench --bench fig5_train_step [-- --workers 1,2,4]

mod common;

use common::{pattern_for, qkv, scores_for, task_shapes, worker_counts, TaskShape};
use spion::attention::dense::{dense_attention_head, dense_attention_train};
use spion::attention::{
    sparse_attention_head_with, sparse_attention_train_with, SparseWorkspace, TrainWorkspace,
};
use spion::config::PatternKind;
use spion::exec::{Exec, ExecConfig, KernelConfig};
use spion::metrics::{attention_bytes_dense, attention_bytes_sparse};
use spion::pattern::BlockMask;
use spion::util::bench::{bench, BenchStats, Report};
use spion::util::human_bytes;
use spion::util::rng::Rng;

#[allow(clippy::too_many_arguments)]
fn bench_model(
    kind: PatternKind,
    shape: &TaskShape,
    mask: &BlockMask,
    exec: &Exec,
    q: &spion::tensor::Mat,
    k: &spion::tensor::Mat,
    v: &spion::tensor::Mat,
    cot: &spion::tensor::Mat,
) -> (BenchStats, BenchStats, usize) {
    let scale = 1.0 / (shape.dh as f32).sqrt();
    if matches!(kind, PatternKind::Dense) {
        let train = bench("train", || {
            let g = dense_attention_train(q, k, v, scale, cot);
            std::hint::black_box(&g);
        });
        let infer = bench("infer", || {
            let (o, _) = dense_attention_head(q, k, v, scale);
            std::hint::black_box(&o);
        });
        (train, infer, attention_bytes_dense(1, 1, shape.l))
    } else {
        let mut ws = TrainWorkspace::new(mask, shape.dh);
        let train = bench("train", || {
            sparse_attention_train_with(exec, q, k, v, scale, cot, &mut ws);
            std::hint::black_box(&ws.dq);
        });
        let mut ws2 = SparseWorkspace::new(mask, shape.dh);
        let infer = bench("infer", || {
            let o = sparse_attention_head_with(exec, q, k, v, scale, &mut ws2);
            std::hint::black_box(&o);
        });
        let mem = attention_bytes_sparse(1, 1, mask.nnz_elements(), mask.nnz_blocks(), mask.lb);
        (train, infer, mem)
    }
}

fn main() {
    let workers_axis = worker_counts();
    let mut rng = Rng::new(0xF15);
    let mut report = Report::new(
        "Fig. 5 — training step time / attention memory / inference time (attention core, per head)",
        &["task", "model", "workers", "kernel", "density", "train step", "vs dense", "memory", "mem red.", "infer", "vs dense"],
    );

    for shape in task_shapes() {
        let scores = scores_for(&shape, &mut rng);
        let (q, k, v) = qkv(&shape, &mut rng);
        let cot = spion::tensor::Mat::random_normal(shape.l, shape.dh, 1.0, &mut rng);

        // Dense baseline: one single-threaded row per task.
        let serial = Exec::serial();
        let full = BlockMask::full(shape.l / shape.block, shape.block);
        let (dense_train, dense_infer, dense_mem) =
            bench_model(PatternKind::Dense, &shape, &full, &serial, &q, &k, &v, &cot);
        report.row(vec![
            shape.name.to_string(),
            "Original".to_string(),
            "1".to_string(),
            "-".to_string(),
            "1.000".to_string(),
            format!("{:.2} ms", dense_train.median_ms),
            "1.00x".to_string(),
            human_bytes(dense_mem),
            "1.00x".to_string(),
            format!("{:.2} ms", dense_infer.median_ms),
            "1.00x".to_string(),
        ]);

        // One mask per model, fixed across the workers axis — every row of
        // the scaling curve measures the same workload (the randomized
        // baselines would otherwise re-draw a different pattern per row).
        let masks: Vec<(PatternKind, BlockMask)> = PatternKind::all()
            .into_iter()
            .filter(|&k| !matches!(k, PatternKind::Dense))
            .map(|kind| (kind, pattern_for(kind, &shape, &scores, &mut rng)))
            .collect();

        // Fused-vs-unfused axis: every sparse model is measured through
        // both kernel regimes at every worker count.
        for &workers in &workers_axis {
            for (kname, kernel) in [
                ("fused", KernelConfig { fused: true, simd: true, fused_bwd: true }),
                ("unfused", KernelConfig { fused: false, simd: false, fused_bwd: false }),
            ] {
                let exec = Exec::new(ExecConfig { workers, kernel, ..Default::default() });
                for (kind, mask) in &masks {
                    let kind = *kind;
                    let (train, infer, mem) =
                        bench_model(kind, &shape, mask, &exec, &q, &k, &v, &cot);
                    report.row(vec![
                        shape.name.to_string(),
                        kind.name().to_string(),
                        workers.to_string(),
                        kname.to_string(),
                        format!("{:.3}", mask.density()),
                        format!("{:.2} ms", train.median_ms),
                        format!("{:.2}x", dense_train.median_ms / train.median_ms),
                        human_bytes(mem),
                        format!("{:.2}x", dense_mem as f64 / mem as f64),
                        format!("{:.2} ms", infer.median_ms),
                        format!("{:.2}x", dense_infer.median_ms / infer.median_ms),
                    ]);
                }
            }
        }
    }
    report.print();
    report.save_csv("results/fig5_train_step.csv");
}
