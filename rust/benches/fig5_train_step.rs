//! Fig. 5 regenerator: per-step training time, attention memory footprint,
//! and per-request inference time for the six compared models on the three
//! tasks.
//!
//! What is measured where (DESIGN.md §2): the *attention core* — the only
//! part that differs between models — runs on the rust block-CSR engine.
//! "Train step" = real forward + real backward (`sparse::backward`, same
//! block structure as the forward, finite-difference-validated). Dense rows
//! are the Original-Transformer baseline. Memory is the score-matrix
//! working set (`metrics::attention_bytes_*`).
//!
//! Paper reference: SPION-CF 1.66× / 2.21× / 3.08× step speedup and 4.62× /
//! 7.23× / 9.64× memory reduction on image / listops / retrieval.
//!
//! Run: cargo bench --bench fig5_train_step

mod common;

use common::{pattern_for, qkv, scores_for, task_shapes, TaskShape};
use spion::attention::dense::{dense_attention_head, dense_attention_train};
use spion::attention::{sparse_attention_head, sparse_attention_train, SparseWorkspace, TrainWorkspace};
use spion::config::PatternKind;
use spion::metrics::{attention_bytes_dense, attention_bytes_sparse};
use spion::pattern::BlockMask;
use spion::util::bench::{bench, BenchStats, Report};
use spion::util::human_bytes;
use spion::util::rng::Rng;

fn bench_model(
    kind: PatternKind,
    shape: &TaskShape,
    mask: &BlockMask,
    q: &spion::tensor::Mat,
    k: &spion::tensor::Mat,
    v: &spion::tensor::Mat,
    cot: &spion::tensor::Mat,
) -> (BenchStats, BenchStats, usize) {
    let scale = 1.0 / (shape.dh as f32).sqrt();
    if matches!(kind, PatternKind::Dense) {
        let train = bench("train", || {
            let g = dense_attention_train(q, k, v, scale, cot);
            std::hint::black_box(&g);
        });
        let infer = bench("infer", || {
            let (o, _) = dense_attention_head(q, k, v, scale);
            std::hint::black_box(&o);
        });
        (train, infer, attention_bytes_dense(1, 1, shape.l))
    } else {
        let mut ws = TrainWorkspace::new(mask, shape.dh);
        let train = bench("train", || {
            sparse_attention_train(q, k, v, scale, cot, &mut ws);
            std::hint::black_box(&ws.dq);
        });
        let mut ws2 = SparseWorkspace::new(mask, shape.dh);
        let infer = bench("infer", || {
            let o = sparse_attention_head(q, k, v, scale, &mut ws2);
            std::hint::black_box(&o);
        });
        let mem = attention_bytes_sparse(1, 1, mask.nnz_elements(), mask.nnz_blocks(), mask.lb);
        (train, infer, mem)
    }
}

fn main() {
    let mut rng = Rng::new(0xF15);
    let mut report = Report::new(
        "Fig. 5 — training step time / attention memory / inference time (attention core, per head)",
        &["task", "model", "density", "train step", "vs dense", "memory", "mem red.", "infer", "vs dense"],
    );

    for shape in task_shapes() {
        let scores = scores_for(&shape, &mut rng);
        let (q, k, v) = qkv(&shape, &mut rng);
        let cot = spion::tensor::Mat::random_normal(shape.l, shape.dh, 1.0, &mut rng);
        let mut dense_train = None;
        let mut dense_mem = 0usize;
        let mut dense_infer = None;
        for kind in PatternKind::all() {
            let mask = pattern_for(kind, &shape, &scores, &mut rng);
            let (train, infer, mem) = bench_model(kind, &shape, &mask, &q, &k, &v, &cot);
            if matches!(kind, PatternKind::Dense) {
                dense_train = Some(train.median_ms);
                dense_infer = Some(infer.median_ms);
                dense_mem = mem;
            }
            report.row(vec![
                shape.name.to_string(),
                kind.name().to_string(),
                format!("{:.3}", mask.density()),
                format!("{:.2} ms", train.median_ms),
                format!("{:.2}x", dense_train.unwrap() / train.median_ms),
                human_bytes(mem),
                format!("{:.2}x", dense_mem as f64 / mem as f64),
                format!("{:.2} ms", infer.median_ms),
                format!("{:.2}x", dense_infer.unwrap() / infer.median_ms),
            ]);
        }
    }
    report.print();
    report.save_csv("results/fig5_train_step.csv");
}
