//! Native-backend full train step: per-step time of the rust full-encoder
//! forward+backward (embedding → N layers → classifier → loss → SGD grads)
//! with dense vs SPION-sparse attention, across exec worker counts and —
//! for the sparse rows — the `fused_bwd` kernel axis (fused two-sweep vs
//! unfused five-pass backward).
//!
//! This is the Fig. 5 comparison lifted from the attention core to the
//! *whole* train step the native backend actually executes — the sparse
//! rows show how much of the paper's attention speedup survives once the
//! (dense) projections/FFN/LayerNorm surround it, and the fused_bwd axis
//! shows how much of the remaining sparse-phase time the fused backward
//! recovers. The loop mirrors NativeTrainer exactly: per-sample gradients
//! and sparse TrainCaches come from step-spanning free-lists and the
//! ordered fold overlaps the fan-out (`par_map_fold`).
//!
//! Writes `BENCH_train.json` — the training perf trajectory file (step
//! time dense vs sparse × fused_bwd × workers).
//!
//! Run: cargo bench --bench native_step [-- --workers 1,2,4 --batch 4]

mod common;

use std::sync::Mutex;

use common::worker_counts;
use spion::config::types::{preset, SparsityConfig};
use spion::config::{ModelConfig, PatternKind};
use spion::exec::{Exec, ExecConfig, KernelConfig};
use spion::model::grad::ModelGrads;
use spion::model::{train_step_sample, ModelParams, TrainCache};
use spion::pattern::spion::synth_attention_scores;
use spion::pattern::{BlockMask, SpionVariant};
use spion::util::bench::{bench, BenchStats, Report};
use spion::util::cli::Args;
use spion::util::rng::Rng;

fn masks_for(model: &ModelConfig, exp_block: usize, alpha: f64) -> Vec<BlockMask> {
    let mut sparsity =
        SparsityConfig::new(PatternKind::Spion(SpionVariant::CF), exp_block, alpha);
    sparsity.pattern.filter = spion::config::types::default_filter(model);
    let mut rng = Rng::new(9);
    (0..model.layers)
        .map(|_| {
            let scores = synth_attention_scores(
                model.seq_len,
                1.0,
                0.3,
                &[model.seq_len / 3],
                0.05,
                &mut rng,
            );
            spion::pattern::spion::generate_pattern(&scores, &sparsity.pattern)
        })
        .collect()
}

struct Row {
    attention: &'static str,
    workers: usize,
    fused_bwd: &'static str,
    stats: BenchStats,
    per_sample_ms: f64,
}

fn main() {
    let args = Args::from_env();
    args.help_if_requested(
        "Native full-encoder train-step bench (dense vs SPION-sparse × fused_bwd)",
        &[
            ("preset <name>", "model preset (default tiny)"),
            ("workers <list>", "comma-separated worker counts (default 1,2,4)"),
            ("batch <n>", "samples per measured step (default: preset batch)"),
            ("alpha <f>", "pattern quantile (default 0.9)"),
        ],
    );
    let preset_name = args.str_or("preset", "tiny");
    let (task, model) = preset(&preset_name).expect("unknown preset");
    let batch = args.usize_or("batch", model.batch);
    let block = spion::config::types::default_block(&model);
    let alpha = args.f64_or("alpha", 0.9);
    let dh = model.d_model / model.heads;

    let params = ModelParams::init_random(&model, 42);
    let masks = masks_for(&model, block, alpha);
    let density: f64 = masks.iter().map(|m| m.density()).sum::<f64>() / masks.len() as f64;
    let gen = spion::data::make_task(task, model.seq_len, model.vocab, model.classes);
    let mut batcher = spion::data::batcher::Batcher::new(gen, batch, 7);
    let b = batcher.next_batch();

    println!(
        "== native_step: preset={preset_name} L={} D={} H={} N={} batch={batch} density={density:.3} ==",
        model.seq_len, model.d_model, model.heads, model.layers
    );
    let mut report = Report::new(
        "Native full train step (fwd+bwd, all parameters)",
        &["attention", "workers", "fused_bwd", "step", "per-sample"],
    );
    let mut rows: Vec<Row> = Vec::new();

    for &workers in &worker_counts() {
        // (attention, fused_bwd label, masks, kernel) — the dense row has
        // no sparse backward, so it carries one kernel config only.
        let cases: [(&'static str, &'static str, Option<&[BlockMask]>, KernelConfig); 3] = [
            ("dense", "-", None, KernelConfig::default()),
            ("spion-cf", "on", Some(masks.as_slice()), KernelConfig::default()),
            (
                "spion-cf",
                "off",
                Some(masks.as_slice()),
                KernelConfig { fused_bwd: false, ..KernelConfig::default() },
            ),
        ];
        for (name, fbwd, layer_masks, kernel) in cases {
            let exec = Exec::new(ExecConfig { workers, kernel, ..Default::default() });
            let inner = exec.serial_view();
            // Step-spanning free-lists, exactly as NativeTrainer keeps them
            // (steady state allocates no ModelGrads / TrainCache).
            let grad_pool: Mutex<Vec<ModelGrads>> = Mutex::new(Vec::with_capacity(batch));
            let cache_pool: Mutex<Vec<TrainCache>> = Mutex::new(Vec::with_capacity(batch));
            let mut grads = ModelGrads::zeros_like(&params);
            let stats = bench(name, || {
                // One batch = the unit the trainer times per step; samples
                // fan out over the pool and fold in order, overlapped.
                grads.zero();
                exec.par_map_fold(
                    batch,
                    |i| {
                        let mut g = match grad_pool.lock().unwrap().pop() {
                            Some(mut g) => {
                                g.zero();
                                g
                            }
                            None => ModelGrads::zeros_like(&params),
                        };
                        let mut cache = layer_masks.map(|ms| {
                            cache_pool
                                .lock()
                                .unwrap()
                                .pop()
                                .unwrap_or_else(|| TrainCache::new(ms, model.heads, dh))
                        });
                        let toks = &b.x[i * model.seq_len..(i + 1) * model.seq_len];
                        train_step_sample(
                            &inner,
                            &params,
                            model.heads,
                            layer_masks,
                            toks,
                            b.y[i],
                            false,
                            &mut g,
                            cache.as_mut(),
                        );
                        (g, cache)
                    },
                    |_, (g, cache)| {
                        grads.add_assign(&g);
                        grad_pool.lock().unwrap().push(g);
                        if let Some(c) = cache {
                            cache_pool.lock().unwrap().push(c);
                        }
                    },
                );
                std::hint::black_box(&grads);
            });
            let per_sample_ms = stats.median_ms / batch as f64;
            report.row(vec![
                name.to_string(),
                workers.to_string(),
                fbwd.to_string(),
                stats.per_iter_human(),
                spion::util::bench::format_ms(per_sample_ms),
            ]);
            rows.push(Row { attention: name, workers, fused_bwd: fbwd, stats, per_sample_ms });
        }
    }
    report.print();
    if let Some(csv) = args.get("out") {
        report.save_csv(csv);
    }

    // Machine-readable training perf trajectory.
    let mut json =
        String::from("{\n  \"bench\": \"native_step\",\n  \"provenance\": \"measured\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"preset\": \"{preset_name}\", \"l\": {}, \"d\": {}, \"heads\": {}, \"layers\": {}, \"batch\": {batch}, \"density\": {density:.4}}},\n",
        model.seq_len, model.d_model, model.heads, model.layers
    ));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"attention\": \"{}\", \"workers\": {}, \"fused_bwd\": \"{}\", \"step_ms\": {:.4}, \"per_sample_ms\": {:.4}}}{}\n",
            r.attention,
            r.workers,
            r.fused_bwd,
            r.stats.median_ms,
            r.per_sample_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_train.json", &json).expect("writing BENCH_train.json");
    println!("wrote BENCH_train.json");
}
