//! Native-backend full train step: per-step time of the rust full-encoder
//! forward+backward (embedding → N layers → classifier → loss → SGD grads)
//! with dense vs SPION-sparse attention, across exec worker counts.
//!
//! This is the Fig. 5 comparison lifted from the attention core to the
//! *whole* train step the native backend actually executes — the sparse
//! rows show how much of the paper's attention speedup survives once the
//! (dense) projections/FFN/LayerNorm surround it.
//!
//! Run: cargo bench --bench native_step [-- --workers 1,2,4 --batch 4]

mod common;

use common::worker_counts;
use spion::config::types::{preset, SparsityConfig};
use spion::config::{ModelConfig, PatternKind};
use spion::exec::{Exec, ExecConfig};
use spion::model::grad::ModelGrads;
use spion::model::{train_step_sample, ModelParams};
use spion::pattern::spion::synth_attention_scores;
use spion::pattern::{BlockMask, SpionVariant};
use spion::util::bench::{bench, Report};
use spion::util::cli::Args;
use spion::util::rng::Rng;

fn masks_for(model: &ModelConfig, exp_block: usize, alpha: f64) -> Vec<BlockMask> {
    let mut sparsity =
        SparsityConfig::new(PatternKind::Spion(SpionVariant::CF), exp_block, alpha);
    sparsity.pattern.filter = spion::config::types::default_filter(model);
    let mut rng = Rng::new(9);
    (0..model.layers)
        .map(|_| {
            let scores = synth_attention_scores(
                model.seq_len,
                1.0,
                0.3,
                &[model.seq_len / 3],
                0.05,
                &mut rng,
            );
            spion::pattern::spion::generate_pattern(&scores, &sparsity.pattern)
        })
        .collect()
}

fn main() {
    let args = Args::from_env();
    args.help_if_requested(
        "Native full-encoder train-step bench (dense vs SPION-sparse)",
        &[
            ("preset <name>", "model preset (default tiny)"),
            ("workers <list>", "comma-separated worker counts (default 1,2,4)"),
            ("batch <n>", "samples per measured step (default: preset batch)"),
            ("alpha <f>", "pattern quantile (default 0.9)"),
        ],
    );
    let preset_name = args.str_or("preset", "tiny");
    let (task, model) = preset(&preset_name).expect("unknown preset");
    let batch = args.usize_or("batch", model.batch);
    let block = spion::config::types::default_block(&model);
    let alpha = args.f64_or("alpha", 0.9);

    let params = ModelParams::init_random(&model, 42);
    let masks = masks_for(&model, block, alpha);
    let density: f64 = masks.iter().map(|m| m.density()).sum::<f64>() / masks.len() as f64;
    let gen = spion::data::make_task(task, model.seq_len, model.vocab, model.classes);
    let mut batcher = spion::data::batcher::Batcher::new(gen, batch, 7);
    let b = batcher.next_batch();

    println!(
        "== native_step: preset={preset_name} L={} D={} H={} N={} batch={batch} density={density:.3} ==",
        model.seq_len, model.d_model, model.heads, model.layers
    );
    let mut report = Report::new(
        "Native full train step (fwd+bwd, all parameters)",
        &["attention", "workers", "step", "per-sample"],
    );

    for &workers in &worker_counts() {
        let exec = Exec::new(ExecConfig::with_workers(workers));
        let inner = exec.serial_view();
        for (name, layer_masks) in [("dense", None), ("spion-cf", Some(masks.as_slice()))] {
            let stats = bench(name, || {
                // One batch = the unit the trainer times per step; samples
                // fan out over the pool exactly as NativeTrainer does.
                let per_sample = exec.par_map(batch, |i| {
                    let mut g = ModelGrads::zeros_like(&params);
                    let toks = &b.x[i * model.seq_len..(i + 1) * model.seq_len];
                    train_step_sample(
                        &inner,
                        &params,
                        model.heads,
                        layer_masks,
                        toks,
                        b.y[i],
                        false,
                        &mut g,
                    );
                    g
                });
                std::hint::black_box(&per_sample);
            });
            report.row(vec![
                name.to_string(),
                workers.to_string(),
                stats.per_iter_human(),
                spion::util::bench::format_ms(stats.median_ms / batch as f64),
            ]);
        }
    }
    report.print();
    if let Some(csv) = args.get("out") {
        report.save_csv(csv);
    }
}
