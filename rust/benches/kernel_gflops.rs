//! Per-kernel GFLOP/s microbench for the block-sparse attention hot path —
//! the perf-trajectory seed for the fused/SIMD kernel layer (ISSUE 2).
//!
//! Measures, on the fig5 tiny listops shape (L=512) with a SPION-CF
//! pattern at B=8 (plus a B=4 row for the second specialized dispatch):
//! * the three unfused kernels in isolation (sddmm / softmax / spmm);
//! * the unfused three-pass pipeline (their sum, measured as one pass);
//! * the fused per-block-row pipeline, SIMD on and off;
//! * the **backward** pipeline (dV/dW/dZ/dQ/dK on the forward's cached
//!   probabilities): unfused five-pass vs the fused two-sweep
//!   (`fused_bwd`), SIMD on and off — the training counterpart rows.
//!
//! The isolated softmax row re-copies the logits each iteration (the kernel
//! is in-place destructive); the memcpy is a few percent of the kernel time
//! and is noted here rather than subtracted. Effective GFLOP/s are computed
//! against the *unfused* op counts for every pipeline row, so fused rates
//! are directly comparable (same work, less time ⇒ higher rate):
//! * sddmm / spmm: `2·nnzb·B²·d` flops each;
//! * softmax: `5` ops per stored entry (cmp + 2 exp + sub + mul; the fused
//!   path executes 4 — it caches the exp — but is charged the same work).
//!
//! Writes `BENCH_kernels.json` (acceptance evidence: fused SIMD ≥ 1.5× the
//! unfused scalar pipeline at workers=1) next to the cargo cwd.
//!
//! Run: cargo bench --bench kernel_gflops [-- --workers 1,2,4]

mod common;

use common::worker_counts;
use spion::attention::{sparse_attention_head_with, SparseWorkspace, TrainWorkspace};
use spion::exec::{Exec, ExecConfig, KernelConfig};
use spion::pattern::spion::{generate_pattern, synth_attention_scores, PatternConfig};
use spion::pattern::SpionVariant;
use spion::sparse::bcsr::Bcsr;
use spion::sparse::sddmm::sddmm_with;
use spion::sparse::softmax::sparse_softmax_with;
use spion::sparse::spmm::spmm_with;
use spion::tensor::Mat;
use spion::util::bench::{bench, BenchStats, Report};
use spion::util::rng::Rng;

const L: usize = 512;
const DH: usize = 32;
const ALPHA: f64 = 0.92;

struct Row {
    workers: usize,
    block: usize,
    kernel: &'static str,
    stats: BenchStats,
    gflops: f64,
}

fn exec_with(workers: usize, kernel: KernelConfig) -> Exec {
    Exec::new(ExecConfig { workers, kernel, ..Default::default() })
}

/// (unfused_fwd_w1, fused_fwd_w1, unfused_bwd_w1, fused_bwd_w1,
/// fused_noobs_w1) medians — the last is the fused forward with the obs
/// span registry disabled, the denominator of the tracing-overhead gate.
fn bench_block_size(
    block: usize,
    workers_axis: &[usize],
    rng: &mut Rng,
    rows: &mut Vec<Row>,
) -> (f64, f64, f64, f64, f64) {
    let scores = synth_attention_scores(L, 1.0, 0.3, &[L / 3, 2 * L / 3], 0.05, rng);
    let cfg = PatternConfig {
        variant: SpionVariant::CF,
        block,
        filter: common::scaled_filter(L),
        alpha: ALPHA,
    };
    let mask = generate_pattern(&scores, &cfg);
    let q = Mat::random_normal(L, DH, 1.0, rng);
    let k = Mat::random_normal(L, DH, 1.0, rng);
    let v = Mat::random_normal(L, DH, 1.0, rng);
    let scale = 1.0 / (DH as f32).sqrt();

    let s0 = Bcsr::from_mask(&mask);
    let nnzb = s0.nnz_blocks() as f64;
    let stored = nnzb * (block * block) as f64;
    let sddmm_flops = 2.0 * stored * DH as f64;
    let spmm_flops = 2.0 * stored * DH as f64;
    let softmax_flops = 5.0 * stored;
    let pipeline_flops = sddmm_flops + softmax_flops + spmm_flops;
    // Backward: 4 GEMM-shaped kernels (dV/dW/dQ/dK) + the two-pair
    // Jacobian — the unfused count charged to every backward row so fused
    // rates are directly comparable (see sparse::ops::engine_bwd_muladds).
    let bwd_flops = 2.0 * (4.0 * stored * DH as f64 + 2.0 * stored);
    let gfl = |flops: f64, st: &BenchStats| flops / (st.median_ms * 1e-3) / 1e9;

    let mut fused_w1_ms = f64::NAN;
    let mut unfused_w1_ms = f64::NAN;
    let mut bwd_fused_w1_ms = f64::NAN;
    let mut bwd_unfused_w1_ms = f64::NAN;
    let mut noobs_w1_ms = f64::NAN;
    for &workers in workers_axis {
        let unfused =
            exec_with(workers, KernelConfig { fused: false, simd: false, fused_bwd: false });
        let fused = exec_with(workers, KernelConfig { fused: true, simd: true, fused_bwd: true });
        let fused_scalar =
            exec_with(workers, KernelConfig { fused: true, simd: false, fused_bwd: true });

        // Isolated kernels (unfused reference forms).
        let mut s = Bcsr::from_mask(&mask);
        let st = bench("sddmm", || sddmm_with(&unfused, &q, &k, &mut s, scale));
        rows.push(Row { workers, block, kernel: "sddmm", gflops: gfl(sddmm_flops, &st), stats: st });

        sddmm_with(&unfused, &q, &k, &mut s, scale);
        let logits = s.values.clone();
        let st = bench("softmax", || {
            s.values.copy_from_slice(&logits); // in-place kernel: restore logits
            sparse_softmax_with(&unfused, &mut s, 1.0, true);
        });
        rows.push(Row { workers, block, kernel: "softmax", gflops: gfl(softmax_flops, &st), stats: st });

        let mut out = Mat::zeros(L, DH);
        let st = bench("spmm", || spmm_with(&unfused, &s, &v, &mut out));
        rows.push(Row { workers, block, kernel: "spmm", gflops: gfl(spmm_flops, &st), stats: st });

        // Whole pipelines through the head entry point (kernel routing).
        for (name, exec) in
            [("unfused", &unfused), ("fused", &fused), ("fused-noSIMD", &fused_scalar)]
        {
            let mut ws = SparseWorkspace::new(&mask, DH);
            let st = bench(name, || {
                let o = sparse_attention_head_with(exec, &q, &k, &v, scale, &mut ws);
                std::hint::black_box(&o);
            });
            if workers == 1 && block == 8 {
                match name {
                    "fused" => fused_w1_ms = st.median_ms,
                    "unfused" => unfused_w1_ms = st.median_ms,
                    _ => {}
                }
            }
            rows.push(Row {
                workers,
                block,
                kernel: name,
                gflops: gfl(pipeline_flops, &st),
                stats: st,
            });
        }

        // Tracing-overhead gate: the same fused pipeline with the obs span
        // registry disabled. The ratio fused/noobs is the cost the always-on
        // spans add to the hottest kernel path (budget: < 2%).
        if workers == 1 && block == 8 {
            let mut ws = SparseWorkspace::new(&mask, DH);
            spion::obs::set_enabled(false);
            let st = bench("fused-noobs", || {
                let o = sparse_attention_head_with(&fused, &q, &k, &v, scale, &mut ws);
                std::hint::black_box(&o);
            });
            spion::obs::set_enabled(true);
            noobs_w1_ms = st.median_ms;
            rows.push(Row {
                workers,
                block,
                kernel: "fused-noobs",
                gflops: gfl(pipeline_flops, &st),
                stats: st,
            });
        }

        // Backward pipelines: one forward fills the cached probabilities,
        // then each regime repeatedly runs the full five-gradient backward
        // over a reused TrainWorkspace (the trainer's steady state).
        for (name, exec) in [
            ("bwd-unfused", &unfused),
            ("bwd-fused", &fused),
            ("bwd-fused-noSIMD", &fused_scalar),
        ] {
            let mut ws = TrainWorkspace::new(&mask, DH);
            sparse_attention_head_with(exec, &q, &k, &v, scale, &mut ws.fwd);
            let cot = Mat::random_normal(L, DH, 1.0, &mut Rng::new(0xC07));
            let st = bench(name, || {
                ws.backward_with(exec, &q, &k, &v, scale, &cot);
                std::hint::black_box(&ws.dq);
            });
            if workers == 1 && block == 8 {
                match name {
                    "bwd-fused" => bwd_fused_w1_ms = st.median_ms,
                    "bwd-unfused" => bwd_unfused_w1_ms = st.median_ms,
                    _ => {}
                }
            }
            rows.push(Row { workers, block, kernel: name, gflops: gfl(bwd_flops, &st), stats: st });
        }
    }
    (unfused_w1_ms, fused_w1_ms, bwd_unfused_w1_ms, bwd_fused_w1_ms, noobs_w1_ms)
}

fn main() {
    let workers_axis = worker_counts();
    let mut rng = Rng::new(0x5EED);
    let mut rows = Vec::new();
    let mut speedup_w1 = f64::NAN;
    let mut bwd_speedup_w1 = f64::NAN;
    let mut obs_overhead_w1 = f64::NAN;
    for block in [8usize, 4] {
        let (unf, fus, bwd_unf, bwd_fus, noobs) =
            bench_block_size(block, &workers_axis, &mut rng, &mut rows);
        if block == 8 {
            speedup_w1 = unf / fus;
            bwd_speedup_w1 = bwd_unf / bwd_fus;
            obs_overhead_w1 = fus / noobs - 1.0;
        }
    }

    let mut report = Report::new(
        "Kernel GFLOP/s — block-sparse attention microkernels (L=512, d=32, SPION-CF)",
        &["B", "workers", "kernel", "median", "GFLOP/s"],
    );
    for r in &rows {
        report.row(vec![
            r.block.to_string(),
            r.workers.to_string(),
            r.kernel.to_string(),
            format!("{:.3} ms", r.stats.median_ms),
            format!("{:.2}", r.gflops),
        ]);
    }
    report.print();
    println!("\nfused-SIMD speedup vs unfused pipeline (L=512, B=8, workers=1): {speedup_w1:.2}x");
    println!("fused-SIMD backward speedup vs unfused backward (L=512, B=8, workers=1): {bwd_speedup_w1:.2}x");
    println!("obs span overhead on fused forward (L=512, B=8, workers=1): {:.2}%", 100.0 * obs_overhead_w1);
    report.save_csv("results/kernel_gflops.csv");

    // Machine-readable evidence for the perf trajectory.
    let mut json = String::from("{\n  \"bench\": \"kernel_gflops\",\n  \"provenance\": \"measured\",\n");
    json.push_str(&format!(
        "  \"config\": {{\"l\": {L}, \"dh\": {DH}, \"alpha\": {ALPHA}, \"blocks\": [8, 4], \"workers\": {workers_axis:?}}},\n"
    ));
    // Only present when the workers axis included 1 (NaN is not JSON).
    if speedup_w1.is_finite() {
        json.push_str(&format!("  \"fused_speedup_w1_b8\": {speedup_w1:.3},\n"));
    }
    if bwd_speedup_w1.is_finite() {
        json.push_str(&format!("  \"fused_bwd_speedup_w1_b8\": {bwd_speedup_w1:.3},\n"));
    }
    if obs_overhead_w1.is_finite() {
        json.push_str(&format!("  \"obs_overhead_fused_w1_b8\": {obs_overhead_w1:.4},\n"));
    }
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"block\": {}, \"workers\": {}, \"kernel\": \"{}\", \"median_ms\": {:.4}, \"gflops\": {:.3}}}{}\n",
            r.block,
            r.workers,
            r.kernel,
            r.stats.median_ms,
            r.gflops,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_kernels.json", &json).expect("writing BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");
}
