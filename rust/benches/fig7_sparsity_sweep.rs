//! Fig. 7 regenerator (timing axis): SPION-C attention-core step time and
//! operation counts across sparsity ratios 70–99% on the ListOps shape.
//! Paper reference: 96% vs 70% sparsity → 3.26× step speedup.
//! (The accuracy axis requires real training → `examples/sparsity_sweep.rs`.)
//!
//! Run: cargo bench --bench fig7_sparsity_sweep

mod common;

use common::{qkv, scores_for, task_shapes};
use spion::attention::{sparse_attention_head, SparseWorkspace};
use spion::pattern::spion::PatternConfig;
use spion::pattern::{generate_pattern, SpionVariant};
use spion::sparse::ops::sparse_total_closed;
use spion::util::bench::{bench, Report};
use spion::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0xF17);
    let shape = task_shapes().remove(1); // listops
    let scores = scores_for(&shape, &mut rng);
    let (q, k, v) = qkv(&shape, &mut rng);
    let scale = 1.0 / (shape.dh as f32).sqrt();

    let mut report = Report::new(
        &format!("Fig. 7 — SPION-C sparsity-ratio sweep ({})", shape.name),
        &["sparsity ratio", "density", "attention ops", "step time", "speedup vs 70%"],
    );

    let ratios = [0.70, 0.80, 0.90, 0.96, 0.99];
    let mut base_ms = None;
    for &ratio in &ratios {
        let cfg = PatternConfig {
            variant: SpionVariant::C,
            block: shape.block,
            filter: common::scaled_filter(shape.l),
            alpha: ratio,
        };
        let mask = generate_pattern(&scores, &cfg);
        let mut ws = SparseWorkspace::new(&mask, shape.dh);
        let t = bench(&format!("ratio{ratio}"), || {
            let o = sparse_attention_head(&q, &k, &v, scale, &mut ws);
            std::hint::black_box(&o);
        });
        if base_ms.is_none() {
            base_ms = Some(t.median_ms);
        }
        let ops = sparse_total_closed(shape.l as u64, shape.dh as u64, mask.nnz_elements() as u64);
        report.row(vec![
            format!("{:.0}%", ratio * 100.0),
            format!("{:.3}", mask.density()),
            format!("{ops}"),
            format!("{:.3} ms", t.median_ms),
            format!("{:.2}x", base_ms.unwrap() / t.median_ms),
        ]);
    }
    report.print();
    report.save_csv("results/fig7_sparsity_sweep.csv");
}
