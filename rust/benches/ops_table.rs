//! §4.4 regenerator: the operation-count analysis table — dense vs sparse
//! MHA op totals (closed form, verified against the per-kernel
//! decomposition and against a mechanically-counted engine pass).
//!
//! Paper reference (AAN, L=4096, D=64, C=10%·L²): 4,328,255,488 vs
//! 432,585,778 → ≈10×. Regenerated EXACTLY, plus the same analysis at the
//! other two task shapes.
//!
//! Run: cargo bench --bench ops_table

mod common;

use spion::pattern::BlockMask;
use spion::sparse::ops::{
    dense_bwd_ops, dense_ops, dense_total_closed, engine_bwd_muladds, sparse_bwd_ops, sparse_ops,
    sparse_total_closed,
};
use spion::util::bench::Report;

/// Mechanical count of multiply-adds an engine SDDMM+SpMM pass performs for
/// a mask (sanity-checks the closed forms against the implementation).
fn measured_muladds(mask: &BlockMask, dh: u64) -> u64 {
    let c = mask.nnz_elements() as u64;
    // SDDMM: dh muls + (dh−1) adds per stored entry → counted as dh mul-adds;
    // SpMM: dh mul-adds per stored entry.
    c * dh + c * dh
}

fn main() {
    let mut report = Report::new(
        "§4.4 — operation counts for the attention core (per head)",
        &["config", "C (nnz)", "dense ops", "sparse ops", "reduction"],
    );

    // Exact paper row: AAN.
    let (l, d) = (4096u64, 64u64);
    let c = 1_677_721u64; // 10% of L², as stated in §4.4
    let dense = dense_total_closed(l, d);
    let sparse = sparse_total_closed(l, d, c);
    assert_eq!(dense, 4_328_255_488, "paper dense total");
    assert_eq!(sparse, 432_585_778, "paper sparse total");
    report.row(vec![
        "AAN paper (L=4096, D=64)".into(),
        format!("{c}"),
        format!("{dense}"),
        format!("{sparse}"),
        format!("{:.2}x", dense as f64 / sparse as f64),
    ]);

    // The three LRA tasks at paper scale, 10% density.
    for (name, l, d) in [
        ("image (L=1024, D=64)", 1024u64, 64u64),
        ("listops (L=2048, D=64)", 2048, 64),
        ("retrieval (L=4096, D=64)", 4096, 64),
    ] {
        let c = l * l / 10;
        let dense = dense_total_closed(l, d);
        let sparse = sparse_total_closed(l, d, c);
        // Cross-check decomposition == closed form.
        assert_eq!(dense_ops(l, d).total(), dense);
        assert_eq!(sparse_ops(l, d, c).total(), sparse);
        report.row(vec![
            name.into(),
            format!("{c}"),
            format!("{dense}"),
            format!("{sparse}"),
            format!("{:.2}x", dense as f64 / sparse as f64),
        ]);
    }

    // Backward (training) totals: the gradient pass keeps the forward's
    // block structure, so its reduction tracks density identically.
    let mut bwd_report = Report::new(
        "operation counts for the attention-core backward (training, per head)",
        &["config", "C (nnz)", "dense bwd ops", "sparse bwd ops", "reduction"],
    );
    for (name, l, d) in [
        ("image (L=1024, D=64)", 1024u64, 64u64),
        ("listops (L=2048, D=64)", 2048, 64),
        ("retrieval (L=4096, D=64)", 4096, 64),
    ] {
        let c = l * l / 10;
        let dense = dense_bwd_ops(l, d).total();
        let sparse = sparse_bwd_ops(l, d, c).total();
        // Full density degrades the sparse decomposition to the dense one.
        assert_eq!(sparse_bwd_ops(l, d, l * l), dense_bwd_ops(l, d));
        bwd_report.row(vec![
            name.into(),
            format!("{c}"),
            format!("{dense}"),
            format!("{sparse}"),
            format!("{:.2}x", dense as f64 / sparse as f64),
        ]);
    }

    // Live-engine cross-check: run one sparse fwd+bwd and compare the
    // stage-split tallies against the analytic counts — the backward is
    // measured with the same fidelity as the forward.
    {
        use spion::attention::{sparse_attention_train_with, TrainWorkspace};
        use spion::exec::Exec;
        use spion::tensor::Mat;
        use spion::util::rng::Rng;
        let mut mask = BlockMask::empty(8, 8);
        mask.set_diagonal();
        for i in 0..8 {
            mask.set(i, 0, true);
        }
        let (l, dh) = (64usize, 16usize);
        let mut rng = Rng::new(4);
        let q = Mat::random_normal(l, dh, 1.0, &mut rng);
        let k = Mat::random_normal(l, dh, 1.0, &mut rng);
        let v = Mat::random_normal(l, dh, 1.0, &mut rng);
        let cot = Mat::random_normal(l, dh, 1.0, &mut rng);
        let exec = Exec::serial();
        let mut ws = TrainWorkspace::new(&mask, dh);
        exec.reset_ops();
        sparse_attention_train_with(&exec, &q, &k, &v, 0.25, &cot, &mut ws);
        let counter = exec.op_counter();
        let stored = mask.nnz_elements() as u64;
        assert_eq!(
            counter.bwd_mul_add,
            engine_bwd_muladds(stored, dh as u64),
            "engine backward tallies match the analytic decomposition"
        );
        assert!(counter.mul_add > 0 && counter.bwd_mul_add > 0);
        bwd_report.row(vec![
            "engine x-check (L=64)".into(),
            format!("{stored}"),
            format!("{} (measured fwd flops)", counter.fwd_flops()),
            format!("{} (measured bwd flops)", counter.bwd_flops()),
            "-".into(),
        ]);
    }

    // Engine cross-check at a small shape: the mechanical mul-add count of
    // the block-CSR engine matches the analytic C·2D term.
    let mut mask = BlockMask::empty(16, 16);
    mask.set_diagonal();
    for i in 0..16 {
        mask.set(i, 0, true);
    }
    let c = mask.nnz_elements() as u64;
    let dh = 32u64;
    let measured = measured_muladds(&mask, dh);
    let analytic = 2 * c * dh;
    assert_eq!(measured, analytic);
    report.row(vec![
        "engine x-check (L=256)".into(),
        format!("{c}"),
        format!("{}", dense_ops(256, dh).qk + dense_ops(256, dh).av),
        format!("{measured} (measured mul-adds ×2)"),
        "-".into(),
    ]);

    report.print();
    bwd_report.print();
    report.save_csv("results/ops_table.csv");
    bwd_report.save_csv("results/ops_table_bwd.csv");
    println!("§4.4 exact paper numbers verified: 4,328,255,488 → 432,585,778 (10.0x)");
}
