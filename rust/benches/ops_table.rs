//! §4.4 regenerator: the operation-count analysis table — dense vs sparse
//! MHA op totals (closed form, verified against the per-kernel
//! decomposition and against a mechanically-counted engine pass).
//!
//! Paper reference (AAN, L=4096, D=64, C=10%·L²): 4,328,255,488 vs
//! 432,585,778 → ≈10×. Regenerated EXACTLY, plus the same analysis at the
//! other two task shapes.
//!
//! Run: cargo bench --bench ops_table

mod common;

use spion::pattern::BlockMask;
use spion::sparse::ops::{dense_ops, dense_total_closed, sparse_ops, sparse_total_closed};
use spion::util::bench::Report;

/// Mechanical count of multiply-adds an engine SDDMM+SpMM pass performs for
/// a mask (sanity-checks the closed forms against the implementation).
fn measured_muladds(mask: &BlockMask, dh: u64) -> u64 {
    let c = mask.nnz_elements() as u64;
    // SDDMM: dh muls + (dh−1) adds per stored entry → counted as dh mul-adds;
    // SpMM: dh mul-adds per stored entry.
    c * dh + c * dh
}

fn main() {
    let mut report = Report::new(
        "§4.4 — operation counts for the attention core (per head)",
        &["config", "C (nnz)", "dense ops", "sparse ops", "reduction"],
    );

    // Exact paper row: AAN.
    let (l, d) = (4096u64, 64u64);
    let c = 1_677_721u64; // 10% of L², as stated in §4.4
    let dense = dense_total_closed(l, d);
    let sparse = sparse_total_closed(l, d, c);
    assert_eq!(dense, 4_328_255_488, "paper dense total");
    assert_eq!(sparse, 432_585_778, "paper sparse total");
    report.row(vec![
        "AAN paper (L=4096, D=64)".into(),
        format!("{c}"),
        format!("{dense}"),
        format!("{sparse}"),
        format!("{:.2}x", dense as f64 / sparse as f64),
    ]);

    // The three LRA tasks at paper scale, 10% density.
    for (name, l, d) in [
        ("image (L=1024, D=64)", 1024u64, 64u64),
        ("listops (L=2048, D=64)", 2048, 64),
        ("retrieval (L=4096, D=64)", 4096, 64),
    ] {
        let c = l * l / 10;
        let dense = dense_total_closed(l, d);
        let sparse = sparse_total_closed(l, d, c);
        // Cross-check decomposition == closed form.
        assert_eq!(dense_ops(l, d).total(), dense);
        assert_eq!(sparse_ops(l, d, c).total(), sparse);
        report.row(vec![
            name.into(),
            format!("{c}"),
            format!("{dense}"),
            format!("{sparse}"),
            format!("{:.2}x", dense as f64 / sparse as f64),
        ]);
    }

    // Engine cross-check at a small shape: the mechanical mul-add count of
    // the block-CSR engine matches the analytic C·2D term.
    let mut mask = BlockMask::empty(16, 16);
    mask.set_diagonal();
    for i in 0..16 {
        mask.set(i, 0, true);
    }
    let c = mask.nnz_elements() as u64;
    let dh = 32u64;
    let measured = measured_muladds(&mask, dh);
    let analytic = 2 * c * dh;
    assert_eq!(measured, analytic);
    report.row(vec![
        "engine x-check (L=256)".into(),
        format!("{c}"),
        format!("{}", dense_ops(256, dh).qk + dense_ops(256, dh).av),
        format!("{measured} (measured mul-adds ×2)"),
        "-".into(),
    ]);

    report.print();
    report.save_csv("results/ops_table.csv");
    println!("§4.4 exact paper numbers verified: 4,328,255,488 → 432,585,778 (10.0x)");
}
