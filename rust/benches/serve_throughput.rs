//! Serving-engine throughput/latency under offered load — the acceptance
//! evidence for the ticketed redesign: sweeps offered load (as a multiple
//! of measured capacity) × `queue_depth` × workers, open-loop (a paced
//! generator that never waits for responses, so overload actually builds
//! up instead of self-throttling like a closed loop would).
//!
//! Reports throughput, p50/p99 response latency, and the rejection rate,
//! as markdown + `results/serve_throughput.csv` + `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench serve_throughput -- --workers 1,2,4`
//! (SPION_BENCH_FAST=1 shrinks the measurement windows ~4×.)

mod common;

use spion::config::ModelConfig;
use spion::model::{Encoder, ModelParams};
use spion::pattern::BlockMask;
use spion::serve::{AdmissionError, Engine, ServeConfig, Ticket};
use spion::util::bench::Report;
use spion::util::rng::Rng;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

/// L=128 D=32 2-layer model with a diagonal block mask (the library's own
/// initializer) — big enough that service time dominates queueing overhead.
fn encoder(seed: u64) -> Encoder {
    let model = ModelConfig {
        preset: "serve-bench".into(),
        seq_len: 128,
        d_model: 32,
        heads: 2,
        layers: 2,
        ffn_dim: 64,
        vocab: 20,
        classes: 4,
        batch: 1,
    };
    let params = ModelParams::init_random(&model, seed);
    let mut mask = BlockMask::empty(8, 16);
    mask.set_diagonal();
    Encoder::new(params, 2).with_masks(vec![mask.clone(), mask]).unwrap()
}

struct Row {
    workers: usize,
    queue_depth: usize,
    offered_x: f64,
    offered_rps: f64,
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    rejection_rate: f64,
}

// Tail latencies come straight from the engine's lock-free
// `latency_histogram` (obs::Hist) rather than a sorted Vec of ticket
// latencies — the bench now reads the same numbers /metrics exposes.

/// One measured service time per request at this worker width, closed
/// loop — the capacity baseline the offered-load multiples scale from.
fn calibrate_capacity_rps(enc: &Encoder, workers: usize, rng: &mut Rng) -> f64 {
    let engine = Engine::start(
        enc.clone(),
        ServeConfig { queue_depth: 64, max_batch: 1, workers, ..Default::default() },
    )
    .unwrap();
    let n = 32;
    let t0 = Instant::now();
    for _ in 0..n {
        let toks: Vec<i32> = (0..128).map(|_| rng.below(20) as i32).collect();
        engine.submit(toks).unwrap().wait().unwrap();
    }
    let rps = n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    engine.shutdown();
    rps
}

fn run_one(
    enc: &Encoder,
    workers: usize,
    queue_depth: usize,
    offered_x: f64,
    capacity_rps: f64,
    window: Duration,
    rng: &mut Rng,
) -> Row {
    let engine = Engine::start(
        enc.clone(),
        ServeConfig { queue_depth, max_batch: 8, workers, ..Default::default() },
    )
    .unwrap();
    let offered_rps = offered_x * capacity_rps;
    let interval = Duration::from_secs_f64(1.0 / offered_rps.max(1.0));
    let start = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::new();
    let mut n = 0u64;
    // Open loop: fire at the pace regardless of responses; spin-wait for
    // the tick (sleep granularity is too coarse at µs intervals).
    while start.elapsed() < window {
        let next = start + interval.mul_f64(n as f64);
        while Instant::now() < next {
            std::hint::spin_loop();
        }
        let toks: Vec<i32> = (0..128).map(|_| rng.below(20) as i32).collect();
        match engine.try_submit(toks) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => {}
            Err(e) => panic!("admission error mid-bench: {e}"),
        }
        n += 1;
    }
    // Drain: wait every admitted ticket so the histogram is complete.
    let drained = tickets.iter().filter(|t| t.wait().is_ok()).count();
    let elapsed = start.elapsed();
    let stats = engine.stats();
    let lat = stats.latency_histogram.snapshot();
    assert_eq!(lat.count, drained as u64, "one histogram sample per served request");
    let row = Row {
        workers,
        queue_depth,
        offered_x,
        offered_rps,
        throughput_rps: stats.served.load(Ordering::Relaxed) as f64 / elapsed.as_secs_f64(),
        p50_ms: lat.percentile(0.50) as f64 / 1e6,
        p99_ms: lat.percentile(0.99) as f64 / 1e6,
        rejection_rate: stats.rejection_rate(),
    };
    assert!(
        stats.queue_peak.load(Ordering::Relaxed) as usize <= queue_depth,
        "bounded-queue invariant violated in bench"
    );
    engine.shutdown();
    row
}

fn main() {
    let fast = std::env::var("SPION_BENCH_FAST").ok().as_deref() == Some("1");
    let window = if fast { Duration::from_millis(250) } else { Duration::from_secs(1) };
    let mut rng = Rng::new(42);
    let enc = encoder(42);

    let mut rows: Vec<Row> = Vec::new();
    for &workers in &common::worker_counts() {
        let capacity = calibrate_capacity_rps(&enc, workers, &mut rng);
        for &queue_depth in &[16usize, 64, 256] {
            for &offered_x in &[0.5f64, 2.0, 4.0] {
                rows.push(run_one(
                    &enc, workers, queue_depth, offered_x, capacity, window, &mut rng,
                ));
            }
        }
    }

    let mut report = Report::new(
        "Serving engine: offered load × queue_depth × workers (open loop)",
        &["workers", "queue_depth", "offered ×cap", "offered req/s", "served req/s", "p50", "p99", "rejected %"],
    );
    for r in &rows {
        report.row(vec![
            r.workers.to_string(),
            r.queue_depth.to_string(),
            format!("{:.1}", r.offered_x),
            format!("{:.0}", r.offered_rps),
            format!("{:.0}", r.throughput_rps),
            format!("{:.2} ms", r.p50_ms),
            format!("{:.2} ms", r.p99_ms),
            format!("{:.1}", 100.0 * r.rejection_rate),
        ]);
    }
    report.print();
    report.save_csv("results/serve_throughput.csv");

    let mut json = String::from("{\n  \"bench\": \"serve_throughput\",\n  \"provenance\": \"measured\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"queue_depth\": {}, \"offered_x\": {:.1}, \"offered_rps\": {:.1}, \"throughput_rps\": {:.1}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"rejection_rate\": {:.4}}}{}\n",
            r.workers,
            r.queue_depth,
            r.offered_x,
            r.offered_rps,
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            r.rejection_rate,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_serve.json", &json).expect("writing BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
