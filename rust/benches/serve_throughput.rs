//! Serving-engine throughput/latency under offered load — the acceptance
//! evidence for the ticketed redesign: sweeps offered load (as a multiple
//! of measured capacity) × `queue_depth` × workers, open-loop (a paced
//! generator that never waits for responses, so overload actually builds
//! up instead of self-throttling like a closed loop would).
//!
//! Two modes in one run:
//!   1. in-process: paced `try_submit` directly against the engine;
//!   2. over-the-socket: paced JSON `POST /v1/infer` through the HTTP
//!      front door on 16 persistent keep-alive connections, sweeping
//!      offered load × priority-class mix — per-class p50/p99 (from the
//!      engine's per-class histograms, the same numbers /metrics exposes)
//!      and the shed/preempt rates under class-aware overload.
//!
//! Reports throughput, p50/p99 response latency, and the rejection rate,
//! as markdown + `results/serve_throughput.csv` + `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench serve_throughput -- --workers 1,2,4`
//! (SPION_BENCH_FAST=1 shrinks the measurement windows ~4×.)

mod common;

use spion::config::ModelConfig;
use spion::model::{Encoder, ModelParams};
use spion::obs::prom::Sources;
use spion::pattern::BlockMask;
use spion::serve::http::{api_router, HttpConfig, HttpServer};
use spion::serve::{AdmissionError, Class, Engine, ServeConfig, Ticket};
use spion::util::bench::Report;
use spion::util::rng::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// L=128 D=32 2-layer model with a diagonal block mask (the library's own
/// initializer) — big enough that service time dominates queueing overhead.
fn encoder(seed: u64) -> Encoder {
    let model = ModelConfig {
        preset: "serve-bench".into(),
        seq_len: 128,
        d_model: 32,
        heads: 2,
        layers: 2,
        ffn_dim: 64,
        vocab: 20,
        classes: 4,
        batch: 1,
    };
    let params = ModelParams::init_random(&model, seed);
    let mut mask = BlockMask::empty(8, 16);
    mask.set_diagonal();
    Encoder::new(params, 2).with_masks(vec![mask.clone(), mask]).unwrap()
}

struct Row {
    workers: usize,
    queue_depth: usize,
    offered_x: f64,
    offered_rps: f64,
    throughput_rps: f64,
    p50_ms: f64,
    p99_ms: f64,
    rejection_rate: f64,
}

// Tail latencies come straight from the engine's lock-free
// `latency_histogram` (obs::Hist) rather than a sorted Vec of ticket
// latencies — the bench now reads the same numbers /metrics exposes.

/// One measured service time per request at this worker width, closed
/// loop — the capacity baseline the offered-load multiples scale from.
fn calibrate_capacity_rps(enc: &Encoder, workers: usize, rng: &mut Rng) -> f64 {
    let engine = Engine::start(
        enc.clone(),
        ServeConfig { queue_depth: 64, max_batch: 1, workers, ..Default::default() },
    )
    .unwrap();
    let n = 32;
    let t0 = Instant::now();
    for _ in 0..n {
        let toks: Vec<i32> = (0..128).map(|_| rng.below(20) as i32).collect();
        engine.submit(toks).unwrap().wait().unwrap();
    }
    let rps = n as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    engine.shutdown();
    rps
}

fn run_one(
    enc: &Encoder,
    workers: usize,
    queue_depth: usize,
    offered_x: f64,
    capacity_rps: f64,
    window: Duration,
    rng: &mut Rng,
) -> Row {
    let engine = Engine::start(
        enc.clone(),
        ServeConfig { queue_depth, max_batch: 8, workers, ..Default::default() },
    )
    .unwrap();
    let offered_rps = offered_x * capacity_rps;
    let interval = Duration::from_secs_f64(1.0 / offered_rps.max(1.0));
    let start = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::new();
    let mut n = 0u64;
    // Open loop: fire at the pace regardless of responses; spin-wait for
    // the tick (sleep granularity is too coarse at µs intervals).
    while start.elapsed() < window {
        let next = start + interval.mul_f64(n as f64);
        while Instant::now() < next {
            std::hint::spin_loop();
        }
        let toks: Vec<i32> = (0..128).map(|_| rng.below(20) as i32).collect();
        match engine.try_submit(toks) {
            Ok(t) => tickets.push(t),
            Err(AdmissionError::QueueFull) => {}
            Err(e) => panic!("admission error mid-bench: {e}"),
        }
        n += 1;
    }
    // Drain: wait every admitted ticket so the histogram is complete.
    let drained = tickets.iter().filter(|t| t.wait().is_ok()).count();
    let elapsed = start.elapsed();
    let stats = engine.stats();
    let lat = stats.latency_histogram.snapshot();
    assert_eq!(lat.count, drained as u64, "one histogram sample per served request");
    let row = Row {
        workers,
        queue_depth,
        offered_x,
        offered_rps,
        throughput_rps: stats.served.load(Ordering::Relaxed) as f64 / elapsed.as_secs_f64(),
        p50_ms: lat.percentile(0.50) as f64 / 1e6,
        p99_ms: lat.percentile(0.99) as f64 / 1e6,
        rejection_rate: stats.rejection_rate(),
    };
    assert!(
        stats.queue_peak.load(Ordering::Relaxed) as usize <= queue_depth,
        "bounded-queue invariant violated in bench"
    );
    engine.shutdown();
    row
}

/// One row of the over-the-socket sweep: a class mix at an offered-load
/// multiple, with per-class server-side latency and the shed breakdown.
struct HttpRow {
    mix: &'static str,
    offered_x: f64,
    offered_rps: f64,
    sent: u64,
    throughput_rps: f64,
    /// Server-side latency per class, indexed by [`Class::index`]; NaN for
    /// a class that served nothing in this cell.
    p50_ms: [f64; Class::COUNT],
    p99_ms: [f64; Class::COUNT],
    /// (rejected + preempted + failed + shed) / (admitted + rejected).
    shed_rate: f64,
    preempted: u64,
}

/// Pick a class from cumulative mix weights (summing to 1).
fn draw_class(mix: &[f64; Class::COUNT], rng: &mut Rng) -> Class {
    let x = rng.below(1000) as f64 / 1000.0;
    let mut acc = 0.0;
    for c in Class::ALL {
        acc += mix[c.index()];
        if x < acc {
            return c;
        }
    }
    Class::BestEffort
}

/// Read one HTTP/1.1 response off the connection and discard it (the bench
/// measures server-side latency from the engine histograms, not wire RTT).
/// A clean EOF on a response boundary comes back as `UnexpectedEof`.
fn discard_response(r: &mut BufReader<std::net::TcpStream>) -> std::io::Result<()> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server closed"));
    }
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "eof in headers"));
        }
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
}

/// Per-connection pipeline cap: a writer that is this far ahead of the
/// responses skips its slot (and the skip is reported) instead of growing
/// the socket buffer without bound — open loop with bounded outstanding.
const MAX_OUTSTANDING: u64 = 64;

/// Open-loop offered load over the socket: `conns` persistent keep-alive
/// connections, each with a paced writer (phase-offset so the aggregate
/// rate is uniform) and an independent reader. Note the front door serves
/// each connection serially (read → dispatch → respond), so per-connection
/// overload queues in the socket buffer; class shedding and preemption
/// still happen inside the engine where connections collide.
fn run_one_http(
    enc: &Encoder,
    mix_name: &'static str,
    mix: [f64; Class::COUNT],
    offered_x: f64,
    capacity_rps: f64,
    window: Duration,
    seed: u64,
) -> HttpRow {
    let conns: usize = 16;
    let workers = 2;
    let engine = Arc::new(
        Engine::start(
            enc.clone(),
            ServeConfig { queue_depth: 8, max_batch: 1, workers, ..Default::default() },
        )
        .unwrap(),
    );
    // One conn worker per persistent connection (a keep-alive connection
    // holds its worker for its whole life), and no per-connection request
    // cap — the pacing decides when the bench ends, not the server.
    let hcfg =
        HttpConfig { conn_workers: conns, keepalive_requests: 1_000_000, ..Default::default() };
    let sources = Sources {
        server: Some(engine.stats().clone()),
        ops: Some(engine.op_tally()),
        health: Some(engine.health()),
    };
    let srv = HttpServer::start(
        "127.0.0.1:0",
        &hcfg,
        api_router(engine.clone(), sources, hcfg.class_share),
    )
    .unwrap();
    let addr = srv.addr();
    let offered_rps = offered_x * capacity_rps;
    let global_interval = Duration::from_secs_f64(1.0 / offered_rps.max(1.0));
    let conn_interval = global_interval.mul_f64(conns as f64);

    let sent_total = Arc::new(AtomicU64::new(0));
    let skipped_total = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..conns)
        .map(|i| {
            let sent_total = sent_total.clone();
            let skipped_total = skipped_total.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed ^ (0x9e37 + i as u64));
                let stream = std::net::TcpStream::connect(addr).expect("connect bench conn");
                stream.set_nodelay(true).ok();
                let mut writer = stream.try_clone().expect("clone bench conn");
                let mut reader = BufReader::new(stream);
                let received = Arc::new(AtomicU64::new(0));
                let recv_count = received.clone();
                // The reader drains until the server closes the connection
                // — which happens after the writer's half-close, once every
                // pipelined request has been answered.
                let rd = std::thread::spawn(move || {
                    while discard_response(&mut reader).is_ok() {
                        recv_count.fetch_add(1, Ordering::AcqRel);
                    }
                });
                let start = Instant::now() + global_interval.mul_f64(i as f64);
                let mut n = 0u64;
                let mut sent = 0u64;
                let mut skipped = 0u64;
                while start.elapsed() < window {
                    let next = start + conn_interval.mul_f64(n as f64);
                    while Instant::now() < next {
                        std::hint::spin_loop();
                    }
                    n += 1;
                    if sent - received.load(Ordering::Acquire) >= MAX_OUTSTANDING {
                        skipped += 1;
                        continue;
                    }
                    let toks: Vec<String> =
                        (0..128).map(|_| rng.below(20).to_string()).collect();
                    let class = draw_class(&mix, &mut rng);
                    let body = format!(
                        "{{\"tokens\": [{}], \"class\": \"{}\"}}",
                        toks.join(","),
                        class.name()
                    );
                    let req = format!(
                        "POST /v1/infer HTTP/1.1\r\nHost: bench\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    if writer.write_all(req.as_bytes()).is_err() {
                        break;
                    }
                    sent += 1;
                }
                // Half-close: the server drains the pipelined backlog,
                // answers everything, then sees EOF and closes — which is
                // what unblocks the reader. (Shutdown acts on the shared
                // socket, so the clone works.)
                let _ = writer.shutdown(std::net::Shutdown::Write);
                let _ = rd.join();
                drop(writer);
                sent_total.fetch_add(sent, Ordering::Relaxed);
                skipped_total.fetch_add(skipped, Ordering::Relaxed);
            })
        })
        .collect();
    for h in handles {
        h.join().expect("bench connection thread");
    }
    // Includes the post-window drain: `served` counts drained responses,
    // so the denominator must cover the time they took.
    let elapsed = t0.elapsed();
    srv.stop();
    engine.shutdown();

    let stats = engine.stats();
    let ld = Ordering::Relaxed;
    let admitted = stats.admitted.load(ld);
    let rejected = stats.rejected.load(ld);
    // `failed` covers deadline expiries (and worker panics, zero here).
    let dropped = rejected
        + stats.preempted.load(ld)
        + stats.failed.load(ld)
        + stats.shed.load(ld);
    let mut p50_ms = [f64::NAN; Class::COUNT];
    let mut p99_ms = [f64::NAN; Class::COUNT];
    for c in Class::ALL {
        let snap = stats.class_latency[c.index()].snapshot();
        if snap.count > 0 {
            p50_ms[c.index()] = snap.percentile(0.50) as f64 / 1e6;
            p99_ms[c.index()] = snap.percentile(0.99) as f64 / 1e6;
        }
    }
    let skipped = skipped_total.load(ld);
    if skipped > 0 {
        println!(
            "  [{mix_name} ×{offered_x:.1}] {skipped} paced slots skipped at the client \
             (outstanding cap {MAX_OUTSTANDING}/conn) — offered rate is net of these"
        );
    }
    HttpRow {
        mix: mix_name,
        offered_x,
        offered_rps,
        sent: sent_total.load(ld),
        throughput_rps: stats.served.load(ld) as f64 / elapsed.as_secs_f64(),
        p50_ms,
        p99_ms,
        shed_rate: dropped as f64 / (admitted + rejected).max(1) as f64,
        preempted: stats.preempted.load(ld),
    }
}

fn main() {
    let fast = std::env::var("SPION_BENCH_FAST").ok().as_deref() == Some("1");
    let window = if fast { Duration::from_millis(250) } else { Duration::from_secs(1) };
    let mut rng = Rng::new(42);
    let enc = encoder(42);

    let mut rows: Vec<Row> = Vec::new();
    for &workers in &common::worker_counts() {
        let capacity = calibrate_capacity_rps(&enc, workers, &mut rng);
        for &queue_depth in &[16usize, 64, 256] {
            for &offered_x in &[0.5f64, 2.0, 4.0] {
                rows.push(run_one(
                    &enc, workers, queue_depth, offered_x, capacity, window, &mut rng,
                ));
            }
        }
    }

    let mut report = Report::new(
        "Serving engine: offered load × queue_depth × workers (open loop)",
        &["workers", "queue_depth", "offered ×cap", "offered req/s", "served req/s", "p50", "p99", "rejected %"],
    );
    for r in &rows {
        report.row(vec![
            r.workers.to_string(),
            r.queue_depth.to_string(),
            format!("{:.1}", r.offered_x),
            format!("{:.0}", r.offered_rps),
            format!("{:.0}", r.throughput_rps),
            format!("{:.2} ms", r.p50_ms),
            format!("{:.2} ms", r.p99_ms),
            format!("{:.1}", 100.0 * r.rejection_rate),
        ]);
    }
    report.print();
    report.save_csv("results/serve_throughput.csv");

    // Over-the-socket open loop: offered load × class mix through the HTTP
    // front door (fixed 2 engine workers, queue depth 8, 16 connections —
    // small queue so class shedding and preemption actually trigger).
    let mixes: [(&'static str, [f64; Class::COUNT]); 3] = [
        ("interactive-heavy", [0.7, 0.2, 0.1]),
        ("balanced", [0.34, 0.33, 0.33]),
        ("batch-heavy", [0.2, 0.3, 0.5]),
    ];
    let capacity = calibrate_capacity_rps(&enc, 2, &mut rng);
    let mut http_rows: Vec<HttpRow> = Vec::new();
    for (i, &(name, mix)) in mixes.iter().enumerate() {
        for &offered_x in &[0.5f64, 2.0, 4.0] {
            http_rows.push(run_one_http(
                &enc,
                name,
                mix,
                offered_x,
                capacity,
                window,
                1000 + i as u64,
            ));
        }
    }

    let fmt_ms = |x: f64| if x.is_nan() { "-".to_string() } else { format!("{x:.2} ms") };
    let mut http_report = Report::new(
        "HTTP front door: offered load × class mix (open loop, 16 keep-alive conns)",
        &[
            "mix", "offered ×cap", "sent", "served req/s", "p50 int", "p99 int", "p50 batch",
            "p99 batch", "p50 be", "p99 be", "shed %", "preempted",
        ],
    );
    for r in &http_rows {
        http_report.row(vec![
            r.mix.to_string(),
            format!("{:.1}", r.offered_x),
            r.sent.to_string(),
            format!("{:.0}", r.throughput_rps),
            fmt_ms(r.p50_ms[Class::Interactive.index()]),
            fmt_ms(r.p99_ms[Class::Interactive.index()]),
            fmt_ms(r.p50_ms[Class::Batch.index()]),
            fmt_ms(r.p99_ms[Class::Batch.index()]),
            fmt_ms(r.p50_ms[Class::BestEffort.index()]),
            fmt_ms(r.p99_ms[Class::BestEffort.index()]),
            format!("{:.1}", 100.0 * r.shed_rate),
            r.preempted.to_string(),
        ]);
    }
    http_report.print();
    http_report.save_csv("results/serve_http_open_loop.csv");

    let mut json = String::from("{\n  \"bench\": \"serve_throughput\",\n  \"provenance\": \"measured\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"queue_depth\": {}, \"offered_x\": {:.1}, \"offered_rps\": {:.1}, \"throughput_rps\": {:.1}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"rejection_rate\": {:.4}}}{}\n",
            r.workers,
            r.queue_depth,
            r.offered_x,
            r.offered_rps,
            r.throughput_rps,
            r.p50_ms,
            r.p99_ms,
            r.rejection_rate,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"http_open_loop\": [\n");
    let jf = |x: f64| if x.is_nan() { "null".to_string() } else { format!("{x:.3}") };
    for (i, r) in http_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mix\": \"{}\", \"offered_x\": {:.1}, \"offered_rps\": {:.1}, \"sent\": {}, \"throughput_rps\": {:.1}, \
             \"p50_ms\": {{\"interactive\": {}, \"batch\": {}, \"best_effort\": {}}}, \
             \"p99_ms\": {{\"interactive\": {}, \"batch\": {}, \"best_effort\": {}}}, \
             \"shed_rate\": {:.4}, \"preempted\": {}}}{}\n",
            r.mix,
            r.offered_x,
            r.offered_rps,
            r.sent,
            r.throughput_rps,
            jf(r.p50_ms[Class::Interactive.index()]),
            jf(r.p50_ms[Class::Batch.index()]),
            jf(r.p50_ms[Class::BestEffort.index()]),
            jf(r.p99_ms[Class::Interactive.index()]),
            jf(r.p99_ms[Class::Batch.index()]),
            jf(r.p99_ms[Class::BestEffort.index()]),
            r.shed_rate,
            r.preempted,
            if i + 1 == http_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_serve.json", &json).expect("writing BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
