//! Dist wire-protocol cost: what one multi-rank training step pays for
//! serialization and the localhost socket hop, isolated from compute.
//!
//! Two axes per message shape:
//! * **codec** — `encode` + `decode` only (pure CPU: framing, no
//!   syscalls), the lower bound a smarter transport could not beat;
//! * **socket** — `write_frame` on one end of a real localhost TCP pair,
//!   `read_frame` (magic + size bound + CRC verify) on the other, acked
//!   per frame — the path `coordinator/dist/` actually runs per step.
//!
//! Message shapes mirror a step at two scales: the Params broadcast
//! (model-sized flat tensors, the dominant coordinator→rank payload) and
//! the per-sample Grads reply (shard-sized, the dominant rank→coordinator
//! payload), plus the Step/Heartbeat control frames as the latency floor.
//!
//! Writes `BENCH_dist.json`.
//!
//! Run: cargo bench --bench dist_step

use spion::coordinator::dist::retry::Deadline;
use spion::coordinator::dist::wire::{decode, encode, read_frame, write_frame, Message, SampleUpdate};
use spion::util::bench::{bench, Report};
use spion::util::rng::Rng;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

// Protocol kind bytes (DESIGN.md §2h wire table) — needed because the
// codec bench feeds `decode` directly instead of reading a frame header.
const KIND_PARAMS: u8 = 3;
const KIND_STEP: u8 = 5;
const KIND_GRADS: u8 = 6;
const KIND_HEARTBEAT: u8 = 7;

/// Flat manifest-order tensors totalling ~`total` f32 elements, split
/// unevenly like a real parameter manifest (embeddings dominate).
fn tensors(total: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<f32>)> {
    let splits = [total / 2, total / 4, total / 8, total - total / 2 - total / 4 - total / 8];
    splits
        .iter()
        .map(|&n| {
            let v: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
            (vec![n], v)
        })
        .collect()
}

fn params_msg(total: usize, rng: &mut Rng) -> Message {
    Message::Params { step: 7, tensors: tensors(total, rng) }
}

fn grads_msg(samples: usize, grad_elems: usize, rng: &mut Rng) -> Message {
    let samples = (0..samples)
        .map(|_| SampleUpdate {
            loss: 1.25,
            correct: true,
            grads: tensors(grad_elems, rng).into_iter().map(|(_, v)| v).collect(),
            scores: None,
        })
        .collect();
    Message::Grads { step: 7, attempt: 0, samples }
}

fn step_msg(seq_len: usize, batch: usize) -> Message {
    Message::Step {
        step: 7,
        attempt: 0,
        snapshot_due: false,
        seq_len: seq_len as u32,
        tokens: vec![3; seq_len * batch],
        labels: vec![1; batch],
    }
}

fn kind_of(msg: &Message) -> u8 {
    match msg {
        Message::Params { .. } => KIND_PARAMS,
        Message::Step { .. } => KIND_STEP,
        Message::Grads { .. } => KIND_GRADS,
        Message::Heartbeat { .. } => KIND_HEARTBEAT,
        other => panic!("bench does not cover {}", other.kind_name()),
    }
}

struct Row {
    name: String,
    path: &'static str,
    frame_bytes: usize,
    mean_ms: f64,
    p95_ms: f64,
    mb_per_s: f64,
}

fn codec_row(name: &str, msg: &Message) -> Row {
    let payload = encode(msg);
    let kind = kind_of(msg);
    let bytes = payload.len() + 13; // header (9) + CRC (4)
    let stats = bench(&format!("codec {name}"), || {
        let p = encode(msg);
        let back = decode(kind, &p).expect("roundtrip decodes");
        std::hint::black_box(back.kind_name());
    });
    Row {
        name: name.to_string(),
        path: "codec",
        frame_bytes: bytes,
        mean_ms: stats.mean_ms,
        p95_ms: stats.p95_ms,
        mb_per_s: bytes as f64 / 1e6 / (stats.mean_ms / 1e3),
    }
}

/// One localhost TCP pair; a sink thread reads+verifies each frame and
/// acks it, so a bench iteration spans serialize → syscalls → parse → CRC.
struct SocketRig {
    tx: TcpStream,
    ack: mpsc::Receiver<()>,
    sink: Option<std::thread::JoinHandle<()>>,
}

impl SocketRig {
    fn new() -> SocketRig {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind bench listener");
        let addr = listener.local_addr().expect("listener addr");
        let tx = TcpStream::connect(addr).expect("connect bench pair");
        let (mut rx, _) = listener.accept().expect("accept bench pair");
        let (ack_tx, ack) = mpsc::channel();
        let sink = std::thread::spawn(move || loop {
            match read_frame(&mut rx, Deadline::after_ms(30_000)) {
                Ok(Message::Shutdown) | Err(_) => return,
                Ok(_) => {
                    if ack_tx.send(()).is_err() {
                        return;
                    }
                }
            }
        });
        SocketRig { tx, ack, sink: Some(sink) }
    }

    fn row(&mut self, name: &str, msg: &Message) -> Row {
        let bytes = encode(msg).len() + 13;
        let stats = bench(&format!("socket {name}"), || {
            write_frame(&mut self.tx, msg, Deadline::after_ms(30_000)).expect("bench write");
            self.ack.recv().expect("sink ack");
        });
        Row {
            name: name.to_string(),
            path: "socket",
            frame_bytes: bytes,
            mean_ms: stats.mean_ms,
            p95_ms: stats.p95_ms,
            mb_per_s: bytes as f64 / 1e6 / (stats.mean_ms / 1e3),
        }
    }
}

impl Drop for SocketRig {
    fn drop(&mut self) {
        let _ = write_frame(&mut self.tx, &Message::Shutdown, Deadline::after_ms(1_000));
        if let Some(h) = self.sink.take() {
            let _ = h.join();
        }
    }
}

fn main() {
    let mut rng = Rng::new(42);
    // ~micro (50k f32 ≈ 200 KB) and ~tiny (1M f32 ≈ 4 MB) parameter sets;
    // grads shards at the micro scale for 2- and 8-sample shards.
    let shapes: Vec<(String, Message)> = vec![
        ("heartbeat".into(), Message::Heartbeat { step: 7 }),
        ("step L=128 b=8".into(), step_msg(128, 8)),
        ("params 50k f32".into(), params_msg(50_000, &mut rng)),
        ("params 1M f32".into(), params_msg(1_000_000, &mut rng)),
        ("grads 2×50k f32".into(), grads_msg(2, 50_000, &mut rng)),
        ("grads 8×50k f32".into(), grads_msg(8, 50_000, &mut rng)),
    ];

    let mut rows = Vec::new();
    for (name, msg) in &shapes {
        rows.push(codec_row(name, msg));
    }
    let mut rig = SocketRig::new();
    for (name, msg) in &shapes {
        rows.push(rig.row(name, msg));
    }
    drop(rig);

    let mut report = Report::new(
        "Dist wire cost per frame (codec vs localhost socket)",
        &["message", "path", "frame bytes", "mean ms", "p95 ms", "MB/s"],
    );
    for r in &rows {
        report.row(vec![
            r.name.clone(),
            r.path.to_string(),
            r.frame_bytes.to_string(),
            format!("{:.4}", r.mean_ms),
            format!("{:.4}", r.p95_ms),
            format!("{:.1}", r.mb_per_s),
        ]);
    }
    report.print();

    let mut json = String::from("{\n  \"dist_wire\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"message\": \"{}\", \"path\": \"{}\", \"frame_bytes\": {}, \"mean_ms\": {:.5}, \
             \"p95_ms\": {:.5}, \"mb_per_s\": {:.1}}}{}\n",
            r.name,
            r.path,
            r.frame_bytes,
            r.mean_ms,
            r.p95_ms,
            r.mb_per_s,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_dist.json", &json).expect("writing BENCH_dist.json");
    println!("wrote BENCH_dist.json");
}
