#![allow(dead_code)]
//! Shared fixtures for the paper-figure benches: per-task attention shapes
//! (paper §5 dimensions, scaled presets by default, paper scale with
//! SPION_BENCH_PAPER=1) and pattern construction for every compared model.

use spion::config::types::SparsityConfig;
use spion::config::PatternKind;
use spion::pattern::spion::{synth_attention_scores, PatternConfig};
use spion::pattern::{bigbird, lsh, BlockMask, SpionVariant};
use spion::tensor::Mat;
use spion::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TaskShape {
    pub name: &'static str,
    /// Sequence length L.
    pub l: usize,
    /// Per-head dim (paper: D = 64, split over H heads → 32; we bench one
    /// head at the paper's D/H).
    pub dh: usize,
    /// Pattern block size B.
    pub block: usize,
    /// Threshold quantile α (paper §5).
    pub alpha: f64,
}

/// The three evaluation tasks. Paper scale: L = 1024 / 2048 / 4096, B = 32 /
/// 64 / 64. Scaled default keeps the B : L ratio and α ordering.
pub fn task_shapes() -> Vec<TaskShape> {
    let paper = std::env::var("SPION_BENCH_PAPER").ok().as_deref() == Some("1");
    if paper {
        vec![
            TaskShape { name: "image (L=1024)", l: 1024, dh: 32, block: 32, alpha: 0.96 },
            TaskShape { name: "listops (L=2048)", l: 2048, dh: 32, block: 64, alpha: 0.98 },
            TaskShape { name: "retrieval (L=4096)", l: 4096, dh: 32, block: 64, alpha: 0.99 },
        ]
    } else {
        vec![
            TaskShape { name: "image (L=256)", l: 256, dh: 32, block: 16, alpha: 0.90 },
            TaskShape { name: "listops (L=512)", l: 512, dh: 32, block: 32, alpha: 0.92 },
            TaskShape { name: "retrieval (L=1024)", l: 1024, dh: 32, block: 64, alpha: 0.94 },
        ]
    }
}

/// Realistic synthetic A^s (diagonal + vertical mixture, Fig. 1 shapes).
pub fn scores_for(shape: &TaskShape, rng: &mut Rng) -> Mat {
    synth_attention_scores(shape.l, 1.0, 0.3, &[shape.l / 3, 2 * shape.l / 3], 0.05, rng)
}

/// Build the block pattern each compared model uses on this task.
pub fn pattern_for(kind: PatternKind, shape: &TaskShape, scores: &Mat, rng: &mut Rng) -> BlockMask {
    let lb = shape.l / shape.block;
    match kind {
        PatternKind::Dense => BlockMask::full(lb, shape.block),
        PatternKind::BigBird => bigbird::bigbird(lb, shape.block, &Default::default(), rng),
        PatternKind::Reformer => lsh::lsh_pattern(scores, shape.block, &Default::default(), rng),
        PatternKind::Spion(variant) => spion::pattern::generate_pattern(
            scores,
            &PatternConfig { variant, block: shape.block, filter: scaled_filter(shape.l), alpha: shape.alpha },
        ),
    }
}

/// QKV fixtures for one head.
pub fn qkv(shape: &TaskShape, rng: &mut Rng) -> (Mat, Mat, Mat) {
    (
        Mat::random_normal(shape.l, shape.dh, 1.0, rng),
        Mat::random_normal(shape.l, shape.dh, 1.0, rng),
        Mat::random_normal(shape.l, shape.dh, 1.0, rng),
    )
}

/// The exec-workers axis for the scaling benches. Priority: `-- --workers
/// 1,2,4` on the bench command line, then `SPION_BENCH_WORKERS`, then the
/// default sweep [1, 2, 4] (`0` entries mean "all cores").
pub fn worker_counts() -> Vec<usize> {
    let from_args = spion::util::cli::Args::from_env()
        .get("workers")
        .map(|s| s.to_string());
    let spec = from_args
        .or_else(|| std::env::var("SPION_BENCH_WORKERS").ok())
        .unwrap_or_else(|| "1,2,4".to_string());
    let counts: Vec<usize> = spec
        .split(',')
        .map(|s| {
            let w: usize = s.trim().parse().unwrap_or_else(|_| panic!("bad workers entry {s:?}"));
            // Same 0-means-all-cores resolution the engine applies.
            spion::exec::ExecConfig::with_workers(w).resolved_workers()
        })
        .collect();
    assert!(!counts.is_empty(), "empty workers axis");
    counts
}

/// Scale-aware diagonal-filter size (mirrors config::types::default_filter).
pub fn scaled_filter(l: usize) -> usize {
    let f = (l / 32).clamp(3, 31);
    if f % 2 == 0 { f + 1 } else { f }
}

#[allow(dead_code)]
pub fn spion_cf() -> PatternKind {
    PatternKind::Spion(SpionVariant::CF)
}

#[allow(dead_code)]
pub fn sparsity_cfg(kind: PatternKind, shape: &TaskShape) -> SparsityConfig {
    SparsityConfig::new(kind, shape.block, shape.alpha)
}
