//! Ablations over the pattern-generation design choices DESIGN.md calls
//! out: block size B, filter size F, threshold α, variant (C/F/CF), and the
//! implicit-zero softmax correction. For each setting we report pattern
//! density, *captured attention mass* (Σ of A^s over retained entries —
//! the quality proxy: how much of the true attention distribution the
//! pattern keeps), pattern-generation latency, and engine step time.
//!
//! Run: cargo bench --bench ablation_pattern

mod common;

use common::{qkv, scores_for, task_shapes};
use spion::attention::{sparse_attention_head, SparseWorkspace};
use spion::pattern::spion::PatternConfig;
use spion::pattern::{generate_pattern, BlockMask, SpionVariant};
use spion::tensor::Mat;
use spion::util::bench::{bench, Report};
use spion::util::rng::Rng;

/// Fraction of total A^s mass covered by the pattern.
fn captured_mass(scores: &Mat, mask: &BlockMask) -> f64 {
    let b = mask.block;
    let mut kept = 0.0f64;
    let mut total = 0.0f64;
    for i in 0..scores.rows {
        for j in 0..scores.cols {
            let v = scores.at(i, j) as f64;
            total += v;
            if mask.get(i / b, j / b) {
                kept += v;
            }
        }
    }
    kept / total.max(1e-12)
}

fn main() {
    let mut rng = Rng::new(0xAB1A);
    let shape = task_shapes().remove(0); // image shape
    let scores = scores_for(&shape, &mut rng);
    let (q, k, v) = qkv(&shape, &mut rng);
    let scale = 1.0 / (shape.dh as f32).sqrt();

    let mut report = Report::new(
        &format!("Ablation — pattern design choices ({})", shape.name),
        &["setting", "density", "captured mass", "gen time", "step time"],
    );

    let mut row = |label: String, cfg: &PatternConfig| {
        let gen_t = bench("gen", || {
            let m = generate_pattern(&scores, cfg);
            std::hint::black_box(&m);
        });
        let mask = generate_pattern(&scores, cfg);
        let mut ws = SparseWorkspace::new(&mask, shape.dh);
        let step_t = bench("step", || {
            let o = sparse_attention_head(&q, &k, &v, scale, &mut ws);
            std::hint::black_box(&o);
        });
        report.row(vec![
            label,
            format!("{:.3}", mask.density()),
            format!("{:.3}", captured_mass(&scores, &mask)),
            format!("{:.3} ms", gen_t.median_ms),
            format!("{:.3} ms", step_t.median_ms),
        ]);
    };

    let base = PatternConfig {
        variant: SpionVariant::CF,
        block: shape.block,
        filter: common::scaled_filter(shape.l),
        alpha: shape.alpha,
    };

    // Variant ablation (the SPION-C / -F / -CF comparison of Table 2).
    for variant in [SpionVariant::C, SpionVariant::F, SpionVariant::CF] {
        row(format!("variant {}", variant.name()), &PatternConfig { variant, ..base.clone() });
    }
    // Block size B.
    for blk in [8, 16, 32, 64] {
        if shape.l % blk == 0 && shape.l / blk >= 4 {
            row(format!("block B={blk}"), &PatternConfig { block: blk, ..base.clone() });
        }
    }
    // Filter size F (paper fixes 31).
    for f in [1, 7, 15, 31] {
        row(format!("filter F={f}"), &PatternConfig { filter: f, ..base.clone() });
    }
    // Threshold α.
    for a in [0.80, 0.90, 0.96, 0.99] {
        row(format!("alpha={a}"), &PatternConfig { alpha: a, ..base.clone() });
    }
    report.print();
    report.save_csv("results/ablation_pattern.csv");

    // Implicit-zero correction ablation: numeric effect on the output.
    let mask = generate_pattern(&scores, &base);
    let mut ws_on = SparseWorkspace::new(&mask, shape.dh);
    let mut ws_off = SparseWorkspace::new(&mask, shape.dh);
    ws_off.zero_correction = false;
    let on = sparse_attention_head(&q, &k, &v, scale, &mut ws_on).clone();
    let off = sparse_attention_head(&q, &k, &v, scale, &mut ws_off).clone();
    let mut diff = 0.0f64;
    let mut norm = 0.0f64;
    for (a, b) in on.data.iter().zip(&off.data) {
        diff += ((a - b) as f64).powi(2);
        norm += (*a as f64).powi(2);
    }
    println!(
        "\nimplicit-zero correction (Alg. 6 line 15): relative output shift {:.4} — \
         dropping it changes the trained model, which is why it is kept on.",
        (diff / norm.max(1e-12)).sqrt()
    );
}
