//! Fig. 6 regenerator: per-operation breakdown of the MHA forward —
//! dense GEMM(QKᵀ) / dense softmax / GEMM(A·V) vs SPION's SDDMM /
//! sparse softmax / SpMM on the block-CSR engine, at each task's shape and
//! the pattern SPION-CF actually extracts — with a **workers axis**: every
//! sparse kernel is re-measured at each `exec` worker count so the scaling
//! curve of the parallel runtime is recorded alongside the dense/sparse
//! comparison (the dense baseline is single-threaded, as in the paper's
//! one-GPU-stream setting).
//!
//! Paper reference points (image task, RTX A5000): SDDMM 2.55×, softmax
//! 42.4×, SpMM 2.54×. The CPU engine reproduces the *shape*: softmax gains
//! dominate, GEMM-replacements gain ≈ the density reciprocal × overhead;
//! the workers axis adds near-linear scaling on top for L large enough.
//!
//! Run: cargo bench --bench fig6_mha_breakdown [-- --workers 1,2,4]
//!      (SPION_BENCH_FAST=1 to smoke, SPION_BENCH_WORKERS=1,8 to override)

mod common;

use common::{pattern_for, qkv, scores_for, task_shapes, worker_counts};
use spion::attention::dense::dense_attention_head;
use spion::exec::{Exec, ExecConfig};
use spion::sparse::bcsr::Bcsr;
use spion::sparse::sddmm::sddmm_with;
use spion::sparse::softmax::sparse_softmax_with;
use spion::sparse::spmm::spmm_with;
use spion::tensor::ops::softmax_rows;
use spion::tensor::Mat;
use spion::util::bench::{bench, Report};
use spion::util::rng::Rng;

fn main() {
    let workers_axis = worker_counts();
    let mut rng = Rng::new(0xF16);
    let mut report = Report::new(
        "Fig. 6 — MHA operation breakdown: dense vs SPION-CF sparse (median ms), by exec workers",
        &["task", "op", "workers", "dense", "sparse", "speedup"],
    );

    for shape in task_shapes() {
        let scores = scores_for(&shape, &mut rng);
        let mask = pattern_for(common::spion_cf(), &shape, &scores, &mut rng);
        let (q, k, v) = qkv(&shape, &mut rng);
        let scale = 1.0 / (shape.dh as f32).sqrt();
        println!(
            "[fig6] {} — pattern density {:.3} ({} blocks), workers axis {:?}",
            shape.name,
            mask.density(),
            mask.nnz_blocks(),
            workers_axis
        );

        // --- dense baselines (single-threaded reference) ---
        let gemm = bench("gemm_qk", || {
            let mut s = q.matmul_nt(&k);
            s.scale(scale);
            std::hint::black_box(&s);
        });
        let mut logits = q.matmul_nt(&k);
        logits.scale(scale);
        let soft_d = bench("softmax_dense", || {
            let mut s = logits.clone();
            softmax_rows(&mut s);
            std::hint::black_box(&s);
        });
        let mut probs = logits.clone();
        softmax_rows(&mut probs);
        let gemm_av = bench("gemm_av", || {
            let out = probs.matmul(&v);
            std::hint::black_box(&out);
        });
        let mha_dense = bench("mha_dense", || {
            let (o, _) = dense_attention_head(&q, &k, &v, scale);
            std::hint::black_box(&o);
        });

        // --- sparse kernels at each worker count ---
        for &workers in &workers_axis {
            let exec = Exec::new(ExecConfig::with_workers(workers));

            let mut s_sparse = Bcsr::from_mask(&mask);
            let sddmm_t = bench("sddmm", || {
                sddmm_with(&exec, &q, &k, &mut s_sparse, scale);
                std::hint::black_box(&s_sparse);
            });

            sddmm_with(&exec, &q, &k, &mut s_sparse, scale);
            let filled = s_sparse.clone();
            let soft_s = bench("softmax_sparse", || {
                let mut s = filled.clone();
                sparse_softmax_with(&exec, &mut s, 1.0, true);
                std::hint::black_box(&s);
            });

            let mut s_prob = filled.clone();
            sparse_softmax_with(&exec, &mut s_prob, 1.0, true);
            let mut out_buf = Mat::zeros(shape.l, shape.dh);
            let spmm_t = bench("spmm", || {
                spmm_with(&exec, &s_prob, &v, &mut out_buf);
                std::hint::black_box(&out_buf);
            });

            let mut ws = spion::attention::SparseWorkspace::new(&mask, shape.dh);
            let mha_sparse = bench("mha_sparse", || {
                let o = spion::attention::sparse_attention_head_with(
                    &exec, &q, &k, &v, scale, &mut ws,
                );
                std::hint::black_box(&o);
            });

            for (op, d, s) in [
                ("QKt (GEMM->SDDMM)", &gemm, &sddmm_t),
                ("softmax (dense->sparse)", &soft_d, &soft_s),
                ("A*V (GEMM->SpMM)", &gemm_av, &spmm_t),
                ("full MHA fwd", &mha_dense, &mha_sparse),
            ] {
                report.row(vec![
                    shape.name.to_string(),
                    op.to_string(),
                    workers.to_string(),
                    format!("{:.3} ms", d.median_ms),
                    format!("{:.3} ms", s.median_ms),
                    format!("{:.2}x", d.median_ms / s.median_ms),
                ]);
            }
        }
    }
    report.print();
    report.save_csv("results/fig6_mha_breakdown.csv");
}
