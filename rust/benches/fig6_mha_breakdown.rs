//! Fig. 6 regenerator: per-operation breakdown of the MHA forward —
//! dense GEMM(QKᵀ) / dense softmax / GEMM(A·V) vs SPION's SDDMM /
//! sparse softmax / SpMM on the block-CSR engine, at each task's shape and
//! the pattern SPION-CF actually extracts — with a **workers axis**: every
//! sparse kernel is re-measured at each `exec` worker count so the scaling
//! curve of the parallel runtime is recorded alongside the dense/sparse
//! comparison (the dense baseline is single-threaded, as in the paper's
//! one-GPU-stream setting).
//!
//! Paper reference points (image task, RTX A5000): SDDMM 2.55×, softmax
//! 42.4×, SpMM 2.54×. The CPU engine reproduces the *shape*: softmax gains
//! dominate, GEMM-replacements gain ≈ the density reciprocal × overhead;
//! the workers axis adds near-linear scaling on top for L large enough.
//!
//! Training rows: the **backward** (dV/dW/dZ/dQ/dK on cached
//! probabilities) is measured with the same dense-vs-sparse treatment —
//! dense `dense_attention_backward_cached` vs the fused two-sweep
//! block-CSR backward — and the sparse engine's measured forward/backward
//! FLOPs (stage-split op tallies) are printed against the §4.4 closed
//! forms, so gradient ops are reported with the same fidelity as the
//! forward.
//!
//! Run: cargo bench --bench fig6_mha_breakdown [-- --workers 1,2,4]
//!      (SPION_BENCH_FAST=1 to smoke, SPION_BENCH_WORKERS=1,8 to override)

mod common;

use common::{pattern_for, qkv, scores_for, task_shapes, worker_counts};
use spion::attention::dense::{dense_attention_backward_cached, dense_attention_head};
use spion::attention::TrainWorkspace;
use spion::exec::{Exec, ExecConfig};
use spion::sparse::ops::{sparse_bwd_ops, sparse_ops};
use spion::sparse::bcsr::Bcsr;
use spion::sparse::sddmm::sddmm_with;
use spion::sparse::softmax::sparse_softmax_with;
use spion::sparse::spmm::spmm_with;
use spion::tensor::ops::softmax_rows;
use spion::tensor::Mat;
use spion::util::bench::{bench, Report};
use spion::util::rng::Rng;

fn main() {
    let workers_axis = worker_counts();
    let mut rng = Rng::new(0xF16);
    let mut report = Report::new(
        "Fig. 6 — MHA operation breakdown: dense vs SPION-CF sparse (median ms), by exec workers",
        &["task", "op", "workers", "dense", "sparse", "speedup"],
    );

    for shape in task_shapes() {
        let scores = scores_for(&shape, &mut rng);
        let mask = pattern_for(common::spion_cf(), &shape, &scores, &mut rng);
        let (q, k, v) = qkv(&shape, &mut rng);
        let scale = 1.0 / (shape.dh as f32).sqrt();
        println!(
            "[fig6] {} — pattern density {:.3} ({} blocks), workers axis {:?}",
            shape.name,
            mask.density(),
            mask.nnz_blocks(),
            workers_axis
        );

        // --- dense baselines (single-threaded reference) ---
        let gemm = bench("gemm_qk", || {
            let mut s = q.matmul_nt(&k);
            s.scale(scale);
            std::hint::black_box(&s);
        });
        let mut logits = q.matmul_nt(&k);
        logits.scale(scale);
        let soft_d = bench("softmax_dense", || {
            let mut s = logits.clone();
            softmax_rows(&mut s);
            std::hint::black_box(&s);
        });
        let mut probs = logits.clone();
        softmax_rows(&mut probs);
        let gemm_av = bench("gemm_av", || {
            let out = probs.matmul(&v);
            std::hint::black_box(&out);
        });
        let mha_dense = bench("mha_dense", || {
            let (o, _) = dense_attention_head(&q, &k, &v, scale);
            std::hint::black_box(&o);
        });
        // Dense backward baseline on cached probabilities (what a training
        // loop actually runs after the forward).
        let (_, dense_probs) = dense_attention_head(&q, &k, &v, scale);
        let cot = {
            let mut r = Rng::new(0xBAD);
            Mat::random_normal(shape.l, shape.dh, 1.0, &mut r)
        };
        let mha_dense_bwd = bench("mha_dense_bwd", || {
            let g = dense_attention_backward_cached(&q, &k, &v, scale, &dense_probs, &cot);
            std::hint::black_box(&g);
        });

        // --- sparse kernels at each worker count ---
        for &workers in &workers_axis {
            let exec = Exec::new(ExecConfig::with_workers(workers));

            let mut s_sparse = Bcsr::from_mask(&mask);
            let sddmm_t = bench("sddmm", || {
                sddmm_with(&exec, &q, &k, &mut s_sparse, scale);
                std::hint::black_box(&s_sparse);
            });

            sddmm_with(&exec, &q, &k, &mut s_sparse, scale);
            let filled = s_sparse.clone();
            let soft_s = bench("softmax_sparse", || {
                let mut s = filled.clone();
                sparse_softmax_with(&exec, &mut s, 1.0, true);
                std::hint::black_box(&s);
            });

            let mut s_prob = filled.clone();
            sparse_softmax_with(&exec, &mut s_prob, 1.0, true);
            let mut out_buf = Mat::zeros(shape.l, shape.dh);
            let spmm_t = bench("spmm", || {
                spmm_with(&exec, &s_prob, &v, &mut out_buf);
                std::hint::black_box(&out_buf);
            });

            let mut ws = spion::attention::SparseWorkspace::new(&mask, shape.dh);
            let mha_sparse = bench("mha_sparse", || {
                let o = spion::attention::sparse_attention_head_with(
                    &exec, &q, &k, &v, scale, &mut ws,
                );
                std::hint::black_box(&o);
            });

            // Sparse backward on the forward's cached probabilities (fused
            // two-sweep, the default training path).
            let mut tws = TrainWorkspace::new(&mask, shape.dh);
            spion::attention::sparse_attention_head_with(&exec, &q, &k, &v, scale, &mut tws.fwd);
            let mha_sparse_bwd = bench("mha_sparse_bwd", || {
                tws.backward_with(&exec, &q, &k, &v, scale, &cot);
                std::hint::black_box(&tws.dq);
            });

            for (op, d, s) in [
                ("QKt (GEMM->SDDMM)", &gemm, &sddmm_t),
                ("softmax (dense->sparse)", &soft_d, &soft_s),
                ("A*V (GEMM->SpMM)", &gemm_av, &spmm_t),
                ("full MHA fwd", &mha_dense, &mha_sparse),
                ("full MHA bwd (cached probs)", &mha_dense_bwd, &mha_sparse_bwd),
            ] {
                report.row(vec![
                    shape.name.to_string(),
                    op.to_string(),
                    workers.to_string(),
                    format!("{:.3} ms", d.median_ms),
                    format!("{:.3} ms", s.median_ms),
                    format!("{:.2}x", d.median_ms / s.median_ms),
                ]);
            }
        }

        // Fidelity check: the engine's stage-split tallies vs the §4.4
        // closed forms, forward AND backward, at this shape's pattern.
        let exec = Exec::serial();
        let mut tws = TrainWorkspace::new(&mask, shape.dh);
        exec.reset_ops();
        spion::attention::sparse_attention_head_with(&exec, &q, &k, &v, scale, &mut tws.fwd);
        tws.backward_with(&exec, &q, &k, &v, scale, &cot);
        let c = exec.op_counter();
        let (lu, du, cu) = (shape.l as u64, shape.dh as u64, mask.nnz_elements() as u64);
        println!(
            "[fig6] {} measured flops — fwd {} (closed form {}), bwd {} (closed form {})",
            shape.name,
            c.fwd_flops(),
            sparse_ops(lu, du, cu).total(),
            c.bwd_flops(),
            sparse_bwd_ops(lu, du, cu).total(),
        );
    }
    report.print();
    report.save_csv("results/fig6_mha_breakdown.csv");
}
