//! Table 2 regenerator: classification accuracy of the six compared models,
//! trained end-to-end through the PJRT stack on the synthetic LRA tasks.
//!
//! Scaled protocol (single-core CPU; DESIGN.md §3): by default trains each
//! model for `SPION_TAB2_STEPS` (default 150) steps on the `tiny` preset,
//! one seed. Set SPION_TAB2_PRESETS=tiny,image,listops,retrieval and/or
//! SPION_TAB2_SEEDS=3 for the fuller (slow) protocol of the recorded run.
//! Absolute accuracy is not comparable to the paper's multi-epoch LRA runs;
//! the claim under test is the ORDERING (SPION-CF ≥ others) and that
//! sparsification does not collapse quality.
//!
//! Run: cargo bench --bench tab2_accuracy

use spion::config::types::{preset, SparsityConfig};
use spion::config::{ExperimentConfig, PatternKind, TrainConfig};
use spion::coordinator::Trainer;
use spion::runtime::Runtime;
use spion::util::bench::Report;

fn env_or(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}

fn main() {
    let presets: Vec<String> =
        env_or("SPION_TAB2_PRESETS", "tiny").split(',').map(|s| s.trim().to_string()).collect();
    let steps: usize = env_or("SPION_TAB2_STEPS", "150").parse().unwrap();
    let seeds: u64 = env_or("SPION_TAB2_SEEDS", "1").parse().unwrap();

    let rt = Runtime::cpu().expect("PJRT client");
    let mut report = Report::new(
        &format!("Table 2 — accuracy ({steps} steps, {seeds} seed(s); scaled protocol)"),
        &["model", "preset", "eval acc", "final loss", "transition", "mean density"],
    );

    for preset_name in &presets {
        let (task, model) = preset(preset_name).expect("unknown preset");
        for kind in PatternKind::all() {
            let mut accs = Vec::new();
            let mut losses = Vec::new();
            let mut transition = None;
            let mut density = f64::NAN;
            for seed in 0..seeds {
                let train = TrainConfig {
                    steps,
                    seed: 42 + seed,
                    // Dense warmup ≈ 20% of the budget (the paper trains
                    // dense "for a few epochs" before sparsifying).
                    max_dense_steps: (steps / 4).max(20),
                    min_dense_steps: (steps / 5).max(10),
                    ..Default::default()
                };
                let exp = ExperimentConfig {
                    task,
                    model: model.clone(),
                    train,
                    sparsity: SparsityConfig::for_model(kind, task, &model),
                    exec: Default::default(),
                    serve: Default::default(),
                    http: Default::default(),
                    obs: Default::default(),
                    resil: Default::default(),
                    dist: Default::default(),
                    artifacts_dir: "artifacts".into(),
                };
                let trainer = Trainer::new(&rt, exp).expect("trainer");
                let outcome = trainer.run().expect("train run");
                let m = outcome.metrics;
                accs.push(m.eval_accuracy.unwrap_or(f64::NAN));
                losses.push(m.final_loss().unwrap_or(f32::NAN));
                transition = m.transition_step;
                if !m.pattern_density.is_empty() {
                    density = m.pattern_density.iter().sum::<f64>() / m.pattern_density.len() as f64;
                }
            }
            let acc = accs.iter().sum::<f64>() / accs.len() as f64;
            let loss = losses.iter().sum::<f32>() / losses.len() as f32;
            println!("[tab2] {preset_name}/{}: acc {acc:.4} loss {loss:.4}", kind.name());
            report.row(vec![
                kind.name().to_string(),
                preset_name.clone(),
                format!("{acc:.4}"),
                format!("{loss:.4}"),
                transition.map(|t| t.to_string()).unwrap_or_else(|| "-".into()),
                if density.is_nan() { "-".into() } else { format!("{density:.3}") },
            ]);
        }
    }
    report.print();
    report.save_csv("results/tab2_accuracy.csv");
}
