"""L2 — the encoder-only Transformer (Algorithm 1) in JAX, with the SPION
sparse MHA (Algorithm 5) wired to the L1 Pallas kernel, plus Adam training
steps. Build-time only: `aot.py` lowers the jitted functions to HLO text;
nothing in this package is imported at run time.

Parameters travel as a FLAT LIST ordered by `configs.param_specs` — the rust
coordinator treats them as opaque buffers and round-trips them between steps,
so ordering is the ABI and is recorded in the artifact manifest.

Dropout is rate-0 (identity): the reproduction runs few-hundred-step budgets
where regularization is irrelevant, and determinism across the
python-reference / rust-runtime boundary is worth more (DESIGN.md §3).
"""

import functools
import os

import jax
import jax.numpy as jnp

from . import configs
from .kernels import ref as kref
from .kernels.spion_attention import block_sparse_attention

LN_EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: configs.ModelConfig, seed):
    """Flat param list in `param_specs` order. `seed` may be a traced u32."""
    key = jax.random.PRNGKey(seed)
    params = []
    for i, (name, shape) in enumerate(configs.param_specs(cfg)):
        k = jax.random.fold_in(key, i)
        base = name.split(".")[-1]
        if base.startswith("ln") or base in ("bf", "be", "cls_b"):
            # LayerNorm gains start at 1, biases at 0.
            init = jnp.ones(shape) if base.endswith("_g") or base == "ln1_g" else jnp.zeros(shape)
            if base in ("ln1_g", "ln2_g"):
                init = jnp.ones(shape)
            params.append(init.astype(jnp.float32))
        elif len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = (1.0 / fan_in) ** 0.5
            params.append(jax.random.normal(k, shape, jnp.float32) * std)
    return params


def _unpack(cfg: configs.ModelConfig, params):
    """Flat list → (embed, pos, [layer dicts], cls_w, cls_b)."""
    it = iter(params)
    embed = next(it)
    pos = next(it)
    layers = []
    names = ["ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "wf", "bf", "we", "be"]
    for _ in range(cfg.layers):
        layers.append({n: next(it) for n in names})
    cls_w = next(it)
    cls_b = next(it)
    return embed, pos, layers, cls_w, cls_b


# ---------------------------------------------------------------------------
# Forward (Algorithm 1 / Algorithm 5)
# ---------------------------------------------------------------------------


def _layernorm(x, g, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * g + b


def _split_heads(x, heads):
    """(B, L, D) → (B·H, L, D/H)."""
    b, l, d = x.shape
    x = x.reshape(b, l, heads, d // heads).transpose(0, 2, 1, 3)
    return x.reshape(b * heads, l, d // heads)


def _merge_heads(x, batch, heads):
    bh, l, dh = x.shape
    x = x.reshape(batch, heads, l, dh).transpose(0, 2, 1, 3)
    return x.reshape(batch, l, heads * dh)


def _dense_mha(q, k, v, heads):
    """Returns (context (B,L,D), head-and-batch-averaged scores (L,L))."""
    b = q.shape[0]
    qh, kh, vh = (_split_heads(t, heads) for t in (q, k, v))
    scale = 1.0 / jnp.sqrt(jnp.float32(qh.shape[-1]))
    out, scores = jax.vmap(lambda qq, kk, vv: kref.dense_attention_ref(qq, kk, vv, scale))(qh, kh, vh)
    return _merge_heads(out, b, heads), scores.mean(axis=0)


#: Sparse-attention lowering choice (build-time env `SPION_SPARSE_IMPL`):
#: * "pallas" (default) — the L1 kernel: streaming row-block schedule with
#:   the BlockSpec structure a real TPU would execute. Under interpret=True
#:   on CPU the emitted while-loop HLO is slower than one fused formula.
#: * "ref" — the dense-equivalent closed form (kernels.ref); XLA fuses it
#:   into a handful of kernels, ~1.9× faster per CPU training step
#:   (EXPERIMENTS.md §Perf). Numerics are identical (pytest asserts
#:   kernel==ref to 1e-5), so this is a pure lowering choice.
SPARSE_IMPL = os.environ.get("SPION_SPARSE_IMPL", "pallas")


def _sparse_mha(q, k, v, heads, block_mask, block):
    b = q.shape[0]
    qh, kh, vh = (_split_heads(t, heads) for t in (q, k, v))
    scale = float(1.0 / (qh.shape[-1] ** 0.5))
    if SPARSE_IMPL == "ref":
        out = kref.mha_sparse_ref(qh, kh, vh, block_mask, block, scale)
    else:
        out = block_sparse_attention(qh, kh, vh, block_mask, block, scale)
    return _merge_heads(out, b, heads)


def forward(cfg: configs.ModelConfig, params, x, masks=None):
    """Encoder forward.

    x: (batch, L) int32 tokens. masks: None for dense, or (layers, LB, LB)
    f32 block masks for the sparse phase. Returns (logits, scores) where
    scores is (layers, L, L) — head/batch-averaged A^s per layer (zeros in
    the sparse phase, where the coordinator no longer needs them).
    """
    embed, pos, layers, cls_w, cls_b = _unpack(cfg, params)
    e = embed[x] + pos[None, :, :]  # (B, L, D)
    score_list = []
    for n, p in enumerate(layers):
        xn = _layernorm(e, p["ln1_g"], p["ln1_b"])
        q = xn @ p["wq"]
        k = xn @ p["wk"]
        v = xn @ p["wv"]
        if masks is None:
            a, scores = _dense_mha(q, k, v, cfg.heads)
            score_list.append(scores)
        else:
            a = _sparse_mha(q, k, v, cfg.heads, masks[n], cfg.pattern_block())
            score_list.append(jnp.zeros((cfg.seq_len, cfg.seq_len), jnp.float32))
        o = a @ p["wo"] + e
        f = jax.nn.relu(_layernorm(o, p["ln2_g"], p["ln2_b"]) @ p["wf"] + p["bf"])
        e = f @ p["we"] + p["be"] + o
    pooled = e.mean(axis=1)
    logits = pooled @ cls_w + cls_b
    return logits, jnp.stack(score_list)


def loss_fn(cfg, params, x, y, masks=None):
    logits, scores = forward(cfg, params, x, masks)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, axis=-1) == y).mean()
    return loss, (scores, acc)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

B1, B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(params, grads, m, v, step, lr):
    """step: i32 (1-based); returns (params', m', v')."""
    t = step.astype(jnp.float32)
    bc1 = 1.0 - B1**t
    bc2 = 1.0 - B2**t
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = B1 * mi + (1.0 - B1) * g
        vi = B2 * vi + (1.0 - B2) * g * g
        update = lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_p.append(p - update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Train / eval entry points (what aot.py lowers)
# ---------------------------------------------------------------------------


def dense_step(cfg, params, m, v, x, y, step, lr):
    """One dense-phase training step.

    Returns (params', m', v', loss, acc, scores)."""
    (loss, (scores, acc)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, x, y, None), has_aux=True
    )(params)
    params, m, v = adam_update(params, grads, m, v, step, lr)
    return params, m, v, loss, acc, scores


def sparse_step(cfg, params, m, v, x, y, step, lr, masks):
    """One sparse-phase training step. Returns (params', m', v', loss, acc)."""
    (loss, (_, acc)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, x, y, masks), has_aux=True
    )(params)
    params, m, v = adam_update(params, grads, m, v, step, lr)
    return params, m, v, loss, acc


def dense_fwd(cfg, params, x):
    logits, _ = forward(cfg, params, x, None)
    return logits


def sparse_fwd(cfg, params, x, masks):
    logits, _ = forward(cfg, params, x, masks)
    return logits


# jit wrappers used by aot.py and the python tests
def jitted(cfg: configs.ModelConfig):
    return {
        "init": jax.jit(functools.partial(init_params, cfg)),
        "dense_step": jax.jit(functools.partial(dense_step, cfg)),
        "sparse_step": jax.jit(functools.partial(sparse_step, cfg)),
        "dense_fwd": jax.jit(functools.partial(dense_fwd, cfg)),
        "sparse_fwd": jax.jit(functools.partial(sparse_fwd, cfg)),
    }
