"""Python reference of the convolutional flood-fill pattern generator
(Algorithms 3+4) — mirrors `rust/src/pattern/` operation-for-operation.

Purpose: cross-language golden vectors. `aot.py` dumps randomized cases
through this module into `artifacts/golden/pattern_golden.json`; the rust
test `rust/tests/golden_parity.rs` replays them through the rust
implementation and demands identical masks (and allclose intermediates).
"""

import numpy as np


def diagonal_filter(f: int) -> np.ndarray:
    return np.full(f, 1.0 / f, dtype=np.float32)


def conv_diag(a: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """Diagonal convolution, zero-padded 'same' (Eq. 3), centered."""
    l = a.shape[0]
    f = len(weights)
    half = f // 2
    out = np.zeros_like(a, dtype=np.float32)
    for fi, w in enumerate(weights):
        off = fi - half
        if off >= 0:
            src = a[off:l, off:l]
            out[: l - off, : l - off] += w * src
        else:
            src = a[: l + off, : l + off]
            out[-off:, -off:] += w * src
    return out


def avg_pool(a: np.ndarray, block: int) -> np.ndarray:
    l = a.shape[0]
    assert l % block == 0
    lb = l // block
    return a.reshape(lb, block, lb, block).mean(axis=(1, 3)).astype(np.float32)


def quantile(values: np.ndarray, q: float) -> float:
    """numpy linear-interpolation quantile over f32 values (matches
    rust/src/pattern/quantile.rs)."""
    return float(np.quantile(values.astype(np.float32).ravel(), q))


def flood_fill_from(pool_out: np.ndarray, r: int, c: int, fl_out: np.ndarray, t: float):
    """Iterative Algorithm 4 walk (same worklist semantics as rust)."""
    lb = pool_out.shape[0]
    stack = [(r, c)]
    while stack:
        r, c = stack.pop()
        if r + 1 >= lb or c + 1 >= lb:
            continue
        right = pool_out[r, c + 1]
        below = pool_out[r + 1, c]
        diag = pool_out[r + 1, c + 1]
        m = max(right, below, diag)
        for nr, nc, val in ((r + 1, c, below), (r, c + 1, right), (r + 1, c + 1, diag)):
            if val == m and fl_out[nr, nc] == 0 and val > t:
                fl_out[nr, nc] = 1
                stack.append((nr, nc))


def flood_fill_all(pool_out: np.ndarray, t: float) -> np.ndarray:
    lb = pool_out.shape[0]
    fl = np.zeros((lb, lb), dtype=np.float32)
    for i in range(lb):
        flood_fill_from(pool_out, 0, i, fl, t)
    for j in range(lb):
        flood_fill_from(pool_out, j, 0, fl, t)
    np.fill_diagonal(fl, 1.0)
    return fl


def generate_pattern(a_s: np.ndarray, variant: str, block: int, filt: int, alpha: float) -> np.ndarray:
    """Algorithm 3. Returns the (LB, LB) 0/1 block mask (pre-upsampling)."""
    a_s = a_s.astype(np.float32)
    conv_out = a_s if variant == "F" else conv_diag(a_s, diagonal_filter(filt))
    pool_out = avg_pool(conv_out, block)
    t = quantile(pool_out, alpha)
    if variant == "C":
        fl = (pool_out > t).astype(np.float32)
        np.fill_diagonal(fl, 1.0)
    elif variant in ("F", "CF"):
        fl = flood_fill_all(pool_out, t)
    else:
        raise ValueError(f"unknown variant {variant}")
    return fl


def synth_scores(l: int, diag_strength: float, vert_strength: float, vert_cols, noise: float, seed: int) -> np.ndarray:
    """Synthetic A^s with controllable shape (NOT required to match the rust
    synth generator — golden cases store the matrix itself)."""
    rng = np.random.default_rng(seed)
    a = rng.random((l, l), dtype=np.float32) * noise
    for i in range(l):
        for w in range(3):
            for j in {max(i - w, 0), min(i + w, l - 1)}:
                a[i, j] += diag_strength / (1.0 + w)
        for c in vert_cols:
            a[i, c] += vert_strength
    a /= np.maximum(a.sum(axis=1, keepdims=True), 1e-9)
    return a.astype(np.float32)
