"""L1 — Pallas block-sparse attention kernel (SDDMM → sparse-softmax → SpMM
fused, flash-attention style) with the paper's implicit-zero softmax.

TPU mapping of the paper's CUDA kernels (DESIGN.md §Hardware-Adaptation):

* CUDA threadblock-per-row + warp reductions (Alg. 6)  →  Pallas grid over
  (batch·head, row-block); each program owns a (B × dh) Q tile in VMEM and
  streams K/V column-blocks through VMEM, carrying a running (max, denom,
  acc) — the row-wise max/sum reductions are vectorized over the tile
  instead of warp-shuffled.
* cuSPARSE SDDMM block skip  →  the block-level mask row weights each
  column block; on real TPU the loop body would sit under `@pl.when(mj > 0)`
  to skip the DMA + MXU work entirely. Under `interpret=True` (the only
  mode the CPU PJRT plugin can execute) both sides of the predicate are
  evaluated, so we fold the mask in arithmetically — identical numerics,
  and the *structural* op saving is measured in the rust engine instead.
* Alg. 6 line 15 (`sum += exp(-max)·(L - b_cnt)`)  →  the `n_pruned`
  correction applied after the streaming pass.

The kernel MUST be lowered with interpret=True for CPU-PJRT execution —
real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _row_block_kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, block, lb, scale):
    """One (batch·head, row-block) program.

    q_ref: (1, block, dh) VMEM tile; k_ref/v_ref: (1, L, dh); m_ref: (1, lb)
    block-mask row; o_ref: (1, block, dh).
    """
    q = q_ref[0]  # (block, dh)
    k = k_ref[0]  # (L, dh)
    v = v_ref[0]  # (L, dh)
    mask_row = m_ref[0]  # (lb,)
    dh = q.shape[-1]

    def body(j, carry):
        m_run, l_run, acc, n_pruned = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=0)  # (block, dh)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=0)
        w = mask_row[j]  # 0.0 or 1.0
        s = (q @ kj.T) * scale  # (block, block) logits
        # Active block: include logits in the running softmax.
        # Pruned block: contributes only to the pruned-entry count.
        blk_max = jnp.where(w > 0, jnp.max(s, axis=-1, keepdims=True), -jnp.inf)
        m_new = jnp.maximum(m_run, blk_max)
        # Rescale previous accumulators to the new max. Guard the -inf − -inf
        # (no active block seen yet) and exp(s − -inf) (pruned block) cases —
        # the accumulators are all zero there, so 0 is the correct factor.
        corr = jnp.where(jnp.isfinite(m_new), jnp.exp(m_run - m_new), 0.0)
        p = jnp.where(
            jnp.isfinite(m_new) & (w > 0), jnp.exp(s - m_new), 0.0
        )  # (block, block); 0 where pruned
        l_new = l_run * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * corr + p @ vj
        n_new = n_pruned + (1.0 - w) * block
        return m_new, l_new, acc_new, n_new

    m0 = jnp.full((block, 1), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((block, 1), dtype=jnp.float32)
    a0 = jnp.zeros((block, dh), dtype=jnp.float32)
    m_run, l_run, acc, n_pruned = jax.lax.fori_loop(0, lb, body, (m0, l0, a0, 0.0))

    # Implicit-zero correction (Alg. 6 line 15): pruned logits are 0, so the
    # true row max is max(m_run, 0) whenever any entry was pruned, and the
    # denominator gains n_pruned · exp(0 − max).
    has_pruned = n_pruned > 0
    m_fin = jnp.where(has_pruned, jnp.maximum(m_run, 0.0), m_run)
    corr = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_fin), 0.0)
    l_fin = l_run * corr + n_pruned * jnp.exp(-m_fin)
    acc_fin = acc * corr
    o_ref[0] = acc_fin / l_fin


@functools.partial(jax.jit, static_argnames=("block", "scale"))
def _pallas_fwd(q, k, v, block_mask, *, block, scale):
    """q, k, v: (BH, L, dh) f32; block_mask: (LB, LB) f32 0/1."""
    bh, l, dh = q.shape
    lb = l // block
    kernel = functools.partial(_row_block_kernel, block=block, lb=lb, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, lb),
        in_specs=[
            pl.BlockSpec((1, block, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, l, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, l, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, lb), lambda b, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, dh), jnp.float32),
        interpret=True,  # CPU-PJRT requirement; see module docstring
    )(q, k, v, block_mask)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward + hand-derived jnp backward.
# Pallas kernels have no automatic transpose rule; the VJP of the masked
# softmax-attention is derived below (standard attention backward with the
# mask folded into both the logits and the probability matrix).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def block_sparse_attention(q, k, v, block_mask, block, scale):
    """Differentiable SPION attention. q,k,v: (BH, L, dh); mask (LB, LB)."""
    return _pallas_fwd(q, k, v, block_mask, block=block, scale=scale)


def _bsa_fwd(q, k, v, block_mask, block, scale):
    out = _pallas_fwd(q, k, v, block_mask, block=block, scale=scale)
    return out, (q, k, v, block_mask)


def _bsa_bwd(block, scale, res, d_out):
    q, k, v, block_mask = res
    p = _ref.upsample_mask(block_mask, block)  # (L, L)

    def one_head(qh, kh, vh, doh):
        logits = (qh @ kh.T) * scale
        masked = logits * p
        m = jnp.max(masked, axis=-1, keepdims=True)
        e = jnp.exp(masked - m)
        denom = jnp.sum(e, axis=-1, keepdims=True)
        a = e / denom  # full-row softmax incl. implicit zeros
        s = a * p  # S^s
        dv = s.T @ doh
        ds = doh @ vh.T  # (L, L)
        da = ds * p
        # softmax backward: dZ = A ⊙ (dA − rowsum(dA ⊙ A))
        dz = a * (da - jnp.sum(da * a, axis=-1, keepdims=True))
        # Z = logits ⊙ P ⇒ d(logits) = dZ ⊙ P
        dl = dz * p * scale
        dq = dl @ kh
        dk = dl.T @ qh
        return dq, dk, dv

    dq, dk, dv = jax.vmap(one_head)(q, k, v, d_out)
    # block_mask is data, not a trainable parameter: zero cotangent.
    return dq, dk, dv, jnp.zeros_like(block_mask)


block_sparse_attention.defvjp(_bsa_fwd, _bsa_bwd)
