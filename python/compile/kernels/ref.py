"""Pure-jnp correctness oracle for the SPION block-sparse attention.

Semantics (paper Eq. 5 + Algorithm 6): pruned logits are imputed as ZERO in
the softmax denominator (not -inf) — Algorithm 6 line 15 adds
``exp(0 - max) * (L - b_cnt)`` — and pruned positions carry no output mass.
The dense-equivalent closed form is

    S^s = softmax((Q Kᵀ · scale) ⊙ P) ⊙ P
    out = S^s V

which is what this oracle computes. Both the Pallas kernel
(`spion_attention.py`) and the rust block-CSR engine are validated against
this module.
"""

import jax
import jax.numpy as jnp


def upsample_mask(block_mask, block: int):
    """Nearest-neighbor upsample of an (LB, LB) 0/1 block mask to (L, L)."""
    m = jnp.repeat(block_mask, block, axis=0)
    return jnp.repeat(m, block, axis=1)


def sparse_attention_ref(q, k, v, p, scale):
    """Single-head reference.

    q, k, v: (L, dh); p: (L, L) 0/1 mask; returns (L, dh).
    """
    logits = (q @ k.T) * scale
    masked = logits * p  # pruned → exactly 0 (paper semantics, NOT -inf)
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.exp(masked - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    s = (e / denom) * p  # pruned positions carry no output mass
    return s @ v


def sparse_attention_scores_ref(q, k, v, p, scale):
    """Reference that also returns S^s (for engine-level golden vectors)."""
    logits = (q @ k.T) * scale
    masked = logits * p
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.exp(masked - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    s = (e / denom) * p
    return s @ v, s


def dense_attention_ref(q, k, v, scale):
    """Dense single-head reference (Algorithm 1 lines 6–8).

    Returns (out, scores)."""
    logits = (q @ k.T) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    s = e / jnp.sum(e, axis=-1, keepdims=True)
    return s @ v, s


def mha_sparse_ref(q, k, v, block_mask, block, scale):
    """Multi-head batched reference. q,k,v: (BH, L, dh); block_mask (LB,LB)."""
    p = upsample_mask(block_mask, block)
    return jax.vmap(lambda qq, kk, vv: sparse_attention_ref(qq, kk, vv, p, scale))(q, k, v)
