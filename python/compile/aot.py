"""AOT pass: lower the L2 model (with the L1 Pallas kernel inside) to HLO
TEXT artifacts the rust runtime loads via `HloModuleProto::from_text_file`.

HLO *text*, not `.serialize()`: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per preset `<p>` this writes
    artifacts/<p>/init.hlo.txt          (seed:u32[]) → params
    artifacts/<p>/dense_step.hlo.txt    params,m,v,x,y,step,lr → …,loss,acc,scores
    artifacts/<p>/sparse_step.hlo.txt   … + masks → …,loss,acc
    artifacts/<p>/dense_fwd.hlo.txt     params,x → logits
    artifacts/<p>/sparse_fwd.hlo.txt    params,x,masks → logits
    artifacts/<p>/manifest.json         shapes + input/output orders (the ABI)
and once globally
    artifacts/golden/pattern_golden.json    python↔rust pattern parity cases
    artifacts/golden/attention_golden.json  sparse-MHA engine parity cases

Usage: python -m compile.aot [--out DIR] [--presets a,b,c] [--force]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, pattern_ref
from .kernels import ref as kref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _example_args(cfg: configs.ModelConfig):
    p = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in configs.param_specs(cfg)]
    m = list(p)
    v = list(p)
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    y = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    masks = jax.ShapeDtypeStruct((cfg.layers, cfg.lb, cfg.lb), jnp.float32)
    return p, m, v, x, y, step, lr, masks


def manifest(cfg: configs.ModelConfig) -> dict:
    specs = configs.param_specs(cfg)
    return {
        "preset": cfg.preset,
        "task": cfg.task,
        "seq_len": cfg.seq_len,
        "d_model": cfg.d_model,
        "heads": cfg.heads,
        "layers": cfg.layers,
        "ffn_dim": cfg.ffn_dim,
        "vocab": cfg.vocab,
        "classes": cfg.classes,
        "batch": cfg.batch,
        "pattern_block": cfg.pattern_block(),
        "lb": cfg.lb,
        "params": [{"name": n, "shape": list(s)} for n, s in specs],
        "io": {
            "init": {"inputs": ["seed:u32[]"], "outputs": ["params*"]},
            "dense_step": {
                "inputs": ["params*", "m*", "v*", "x:i32[batch,L]", "y:i32[batch]", "step:i32[]", "lr:f32[]"],
                "outputs": ["params*", "m*", "v*", "loss:f32[]", "acc:f32[]", "scores:f32[layers,L,L]"],
            },
            "sparse_step": {
                "inputs": [
                    "params*", "m*", "v*", "x:i32[batch,L]", "y:i32[batch]",
                    "step:i32[]", "lr:f32[]", "masks:f32[layers,lb,lb]",
                ],
                "outputs": ["params*", "m*", "v*", "loss:f32[]", "acc:f32[]"],
            },
            "dense_fwd": {"inputs": ["params*", "x:i32[batch,L]"], "outputs": ["logits:f32[batch,classes]"]},
            "sparse_fwd": {
                "inputs": ["params*", "x:i32[batch,L]", "masks:f32[layers,lb,lb]"],
                "outputs": ["logits:f32[batch,classes]"],
            },
        },
    }


def emit_preset(cfg: configs.ModelConfig, out_dir: str, force: bool) -> None:
    pdir = os.path.join(out_dir, cfg.preset)
    os.makedirs(pdir, exist_ok=True)
    fns = model.jitted(cfg)
    p, m, v, x, y, step, lr, masks = _example_args(cfg)
    plans = {
        "init": (fns["init"], (jax.ShapeDtypeStruct((), jnp.uint32),)),
        "dense_step": (fns["dense_step"], (p, m, v, x, y, step, lr)),
        "sparse_step": (fns["sparse_step"], (p, m, v, x, y, step, lr, masks)),
        "dense_fwd": (fns["dense_fwd"], (p, x)),
        "sparse_fwd": (fns["sparse_fwd"], (p, x, masks)),
    }
    for name, (fn, args) in plans.items():
        path = os.path.join(pdir, f"{name}.hlo.txt")
        if os.path.exists(path) and not force:
            print(f"[aot] keep {path}")
            continue
        text = to_hlo_text(fn.lower(*args))
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] wrote {path} ({len(text) / 1e6:.2f} MB)")
    mpath = os.path.join(pdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(cfg), f, indent=1, sort_keys=True)
    print(f"[aot] wrote {mpath}")


# ---------------------------------------------------------------------------
# Golden vectors (python ↔ rust parity)
# ---------------------------------------------------------------------------


def pattern_golden_cases() -> dict:
    cases = []
    specs = [
        # (l, block, filt, alpha, variant, shape args)
        (64, 8, 5, 0.90, "CF", dict(diag=1.0, vert=0.0, cols=[], noise=0.05, seed=11)),
        (64, 8, 5, 0.90, "C", dict(diag=1.0, vert=0.0, cols=[], noise=0.05, seed=12)),
        (64, 8, 1, 0.85, "F", dict(diag=0.3, vert=1.0, cols=[17, 18], noise=0.02, seed=13)),
        (128, 16, 7, 0.95, "CF", dict(diag=0.8, vert=0.6, cols=[40], noise=0.05, seed=14)),
        (96, 8, 31, 0.92, "CF", dict(diag=0.5, vert=0.0, cols=[5], noise=0.10, seed=15)),
    ]
    for l, block, filt, alpha, variant, s in specs:
        a = pattern_ref.synth_scores(l, s["diag"], s["vert"], s["cols"], s["noise"], s["seed"])
        conv = a if variant == "F" else pattern_ref.conv_diag(a, pattern_ref.diagonal_filter(filt))
        pool = pattern_ref.avg_pool(conv, block)
        t = pattern_ref.quantile(pool, alpha)
        mask = pattern_ref.generate_pattern(a, variant, block, filt, alpha)
        fl_from_pool = (
            pattern_ref.flood_fill_all(pool, t) if variant in ("F", "CF") else None
        )
        cases.append(
            {
                "l": l,
                "block": block,
                "filter": filt,
                "alpha": alpha,
                "variant": variant,
                "scores": [round(float(x), 8) for x in a.ravel()],
                "conv_out": [round(float(x), 8) for x in conv.ravel()],
                "pool_out": [round(float(x), 8) for x in pool.ravel()],
                "threshold": float(t),
                "mask": [int(x) for x in mask.ravel()],
                "flood_from_pool": None
                if fl_from_pool is None
                else [int(x) for x in fl_from_pool.ravel()],
            }
        )
    return {"cases": cases}


def attention_golden_cases() -> dict:
    cases = []
    rng = np.random.default_rng(7)
    for (l, dh, block, keep) in [(32, 8, 8, 0.5), (64, 16, 16, 0.2), (48, 4, 8, 1.0)]:
        lb = l // block
        q = rng.standard_normal((l, dh), dtype=np.float32)
        k = rng.standard_normal((l, dh), dtype=np.float32)
        v = rng.standard_normal((l, dh), dtype=np.float32)
        bm = (rng.random((lb, lb)) < keep).astype(np.float32)
        np.fill_diagonal(bm, 1.0)
        scale = 1.0 / np.sqrt(dh)
        p = np.asarray(kref.upsample_mask(jnp.asarray(bm), block))
        out, s = kref.sparse_attention_scores_ref(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(p), scale
        )
        dense_out, _ = kref.dense_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), scale)
        cases.append(
            {
                "l": l,
                "dh": dh,
                "block": block,
                "scale": float(scale),
                "q": q.ravel().tolist(),
                "k": k.ravel().tolist(),
                "v": v.ravel().tolist(),
                "block_mask": bm.astype(int).ravel().tolist(),
                "out": np.asarray(out).ravel().tolist(),
                "s_sparse": np.asarray(s).ravel().tolist(),
                "dense_out": np.asarray(dense_out).ravel().tolist(),
            }
        )
    return {"cases": cases}


def emit_golden(out_dir: str) -> None:
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    for name, payload in [
        ("pattern_golden.json", pattern_golden_cases()),
        ("attention_golden.json", attention_golden_cases()),
    ]:
        path = os.path.join(gdir, name)
        with open(path, "w") as f:
            json.dump(payload, f)
        print(f"[aot] wrote {path}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default=",".join(configs.DEFAULT_PRESETS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    names = [n.strip() for n in args.presets.split(",") if n.strip()]
    for name in names:
        cfg = configs.BY_NAME.get(name)
        if cfg is None:
            print(f"[aot] unknown preset {name!r}", file=sys.stderr)
            return 1
        emit_preset(cfg, args.out, args.force)
    emit_golden(args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
