"""Model/task presets — MUST mirror `rust/src/config/types.rs::presets()`.

The AOT pass bakes these shapes into the HLO artifacts; the rust launcher
looks artifacts up by preset name and checks the manifest against its own
copy of the preset table (rust/tests/artifact_manifest.rs).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    preset: str
    task: str
    seq_len: int
    d_model: int
    heads: int
    layers: int
    ffn_dim: int
    vocab: int
    classes: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads

    def pattern_block(self) -> int:
        """Default pattern block size (mirrors config::types::default_block)."""
        return max(8, min(64, self.seq_len // 16))

    @property
    def lb(self) -> int:
        b = self.pattern_block()
        assert self.seq_len % b == 0
        return self.seq_len // b


PRESETS = [
    ModelConfig("tiny", "listops", 128, 32, 2, 2, 64, 20, 10, 8),
    ModelConfig("image", "image", 256, 64, 2, 2, 128, 256, 10, 16),
    ModelConfig("listops", "listops", 256, 64, 2, 2, 128, 20, 10, 16),
    ModelConfig("retrieval", "retrieval", 512, 64, 2, 2, 128, 64, 2, 8),
    ModelConfig("image-paper", "image", 1024, 64, 2, 4, 128, 256, 10, 4),
    ModelConfig("listops-paper", "listops", 2048, 64, 2, 4, 128, 20, 10, 2),
    ModelConfig("retrieval-paper", "retrieval", 4096, 64, 2, 4, 128, 64, 2, 1),
]

BY_NAME = {c.preset: c for c in PRESETS}

#: presets compiled by default (`make artifacts`); the -paper shapes are
#: compile-heavy and built on demand (`make artifacts-paper`).
DEFAULT_PRESETS = ["tiny", "image", "listops", "retrieval"]


def param_specs(cfg: ModelConfig):
    """Flat parameter layout: [(name, shape), …] — the single source of truth
    for both the python model and the rust checkpoint format."""
    d, f = cfg.d_model, cfg.ffn_dim
    specs = [("embed", (cfg.vocab, d)), ("pos", (cfg.seq_len, d))]
    for n in range(cfg.layers):
        specs += [
            (f"l{n}.ln1_g", (d,)),
            (f"l{n}.ln1_b", (d,)),
            (f"l{n}.wq", (d, d)),
            (f"l{n}.wk", (d, d)),
            (f"l{n}.wv", (d, d)),
            (f"l{n}.wo", (d, d)),
            (f"l{n}.ln2_g", (d,)),
            (f"l{n}.ln2_b", (d,)),
            (f"l{n}.wf", (d, f)),
            (f"l{n}.bf", (f,)),
            (f"l{n}.we", (f, d)),
            (f"l{n}.be", (d,)),
        ]
    specs += [("cls_w", (d, cfg.classes)), ("cls_b", (cfg.classes,))]
    return specs
