"""Tests for the python pattern-generation reference (Algorithms 3+4)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pattern_ref as pr


def test_fig4_walkthrough_diagonal_band():
    pool = np.array(
        [
            [0.9, 0.1, 0.0, 0.0],
            [0.1, 0.8, 0.1, 0.0],
            [0.0, 0.1, 0.7, 0.1],
            [0.0, 0.0, 0.1, 0.9],
        ],
        dtype=np.float32,
    )
    fl = np.zeros((4, 4), dtype=np.float32)
    pr.flood_fill_from(pool, 0, 0, fl, 0.5)
    assert fl[1, 1] == 1 and fl[2, 2] == 1 and fl[3, 3] == 1
    assert fl[0, 1] == 0 and fl[1, 0] == 0


def test_flood_threshold_blocks_all():
    pool = np.full((6, 6), 0.3, dtype=np.float32)
    fl = pr.flood_fill_all(pool, 0.9)
    assert (fl == np.eye(6)).all()


def test_conv_identity():
    a = np.arange(25, dtype=np.float32).reshape(5, 5)
    out = pr.conv_diag(a, np.array([1.0], dtype=np.float32))
    np.testing.assert_allclose(out, a)


def test_conv_diagonal_amplification():
    l = 16
    a = np.zeros((l, l), dtype=np.float32)
    np.fill_diagonal(a, 1.0)
    a[2, 9] = 1.0
    out = pr.conv_diag(a, pr.diagonal_filter(5))
    assert out[8, 8] > 2 * out[2, 9]


def test_avg_pool_known():
    a = np.array([[1, 2], [3, 4]], dtype=np.float32)
    assert pr.avg_pool(a, 2)[0, 0] == 2.5


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    lb=st.integers(2, 8),
    block=st.sampled_from([2, 4, 8]),
    alpha=st.floats(0.5, 0.99),
    variant=st.sampled_from(["C", "F", "CF"]),
)
def test_pattern_invariants(seed, lb, block, alpha, variant):
    rng = np.random.default_rng(seed)
    l = lb * block
    a = rng.random((l, l), dtype=np.float32)
    mask = pr.generate_pattern(a, variant, block, 5, alpha)
    assert mask.shape == (lb, lb)
    assert set(np.unique(mask)).issubset({0.0, 1.0})
    assert (np.diag(mask) == 1).all(), "diagonal forced on"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), t1=st.floats(0, 1), t2=st.floats(0, 1))
def test_flood_monotone_in_threshold(seed, t1, t2):
    rng = np.random.default_rng(seed)
    pool = rng.random((8, 8)).astype(np.float32)
    lo, hi = min(t1, t2), max(t1, t2)
    fl_lo = pr.flood_fill_all(pool, lo)
    fl_hi = pr.flood_fill_all(pool, hi)
    assert (fl_lo >= fl_hi).all()


def test_spion_c_density_tracks_alpha():
    a = pr.synth_scores(128, 0.8, 0.2, [30], 0.05, 3)
    m_dense = pr.generate_pattern(a, "C", 16, 5, 0.70)
    m_sparse = pr.generate_pattern(a, "C", 16, 5, 0.95)
    assert m_dense.sum() >= m_sparse.sum()


def test_quantile_matches_numpy():
    rng = np.random.default_rng(0)
    v = rng.standard_normal(100).astype(np.float32)
    for q in [0.0, 0.25, 0.5, 0.9, 1.0]:
        assert pr.quantile(v, q) == pytest.approx(float(np.quantile(v, q)), rel=1e-6)
