"""L1 correctness: Pallas block-sparse attention vs the pure-jnp oracle.

The CORE correctness signal of the compile path: hypothesis sweeps shapes,
densities and scales; every case must match `kernels.ref` to float32
tolerance, including the implicit-zero softmax semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spion_attention import _pallas_fwd, block_sparse_attention

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-4, atol=1e-5)


def _mk_case(seed, bh, lb, block, dh, keep):
    rng = np.random.default_rng(seed)
    l = lb * block
    q = rng.standard_normal((bh, l, dh), dtype=np.float32)
    k = rng.standard_normal((bh, l, dh), dtype=np.float32)
    v = rng.standard_normal((bh, l, dh), dtype=np.float32)
    mask = (rng.random((lb, lb)) < keep).astype(np.float32)
    np.fill_diagonal(mask, 1.0)
    return q, k, v, mask


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bh=st.integers(1, 4),
    lb=st.integers(1, 6),
    block=st.sampled_from([4, 8, 16]),
    dh=st.sampled_from([4, 8, 16, 32]),
    keep=st.floats(0.0, 1.0),
)
def test_pallas_matches_ref_sweep(seed, bh, lb, block, dh, keep):
    q, k, v, mask = _mk_case(seed, bh, lb, block, dh, keep)
    scale = 1.0 / np.sqrt(dh)
    got = _pallas_fwd(q, k, v, mask, block=block, scale=scale)
    expect = ref.mha_sparse_ref(q, k, v, mask, block, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), **TOL)
    assert np.isfinite(np.asarray(got)).all()


def test_full_mask_equals_dense():
    q, k, v, _ = _mk_case(0, 2, 4, 8, 8, 1.0)
    mask = np.ones((4, 4), np.float32)
    scale = 1.0 / np.sqrt(8)
    got = _pallas_fwd(q, k, v, mask, block=8, scale=scale)
    dense = jax.vmap(lambda a, b, c: ref.dense_attention_ref(a, b, c, scale)[0])(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense), **TOL)


def test_diagonal_only_mask():
    q, k, v, _ = _mk_case(3, 1, 4, 8, 8, 0.0)
    mask = np.eye(4, dtype=np.float32)
    scale = 0.35
    got = _pallas_fwd(q, k, v, mask, block=8, scale=scale)
    expect = ref.mha_sparse_ref(q, k, v, mask, 8, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), **TOL)


def test_zero_imputation_differs_from_neg_inf_masking():
    """The paper's semantics (pruned logit = 0) is NOT the common -inf
    masking; the kernel must implement the former."""
    q, k, v, mask = _mk_case(5, 1, 4, 8, 8, 0.4)
    scale = 1.0 / np.sqrt(8)
    got = np.asarray(_pallas_fwd(q, k, v, mask, block=8, scale=scale))

    p = np.asarray(ref.upsample_mask(jnp.asarray(mask), 8))

    def neg_inf_attention(qh, kh, vh):
        logits = (qh @ kh.T) * scale
        logits = np.where(p > 0, logits, -np.inf)
        m = logits.max(-1, keepdims=True)
        e = np.exp(logits - m)
        s = e / e.sum(-1, keepdims=True)
        return s @ vh

    neg_inf = np.stack([neg_inf_attention(q[i], k[i], v[i]) for i in range(q.shape[0])])
    # They must differ (unless the mask is full, which 0.4 keep is not).
    assert np.abs(got - neg_inf).max() > 1e-3


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), keep=st.floats(0.1, 1.0))
def test_custom_vjp_matches_ref_grad(seed, keep):
    q, k, v, mask = _mk_case(seed, 1, 3, 8, 8, keep)
    scale = 1.0 / np.sqrt(8)
    rng = np.random.default_rng(seed + 1)
    cot = rng.standard_normal(q.shape, dtype=np.float32)

    def f_kernel(q, k, v):
        return (block_sparse_attention(q, k, v, mask, 8, scale) * cot).sum()

    def f_ref(q, k, v):
        return (ref.mha_sparse_ref(q, k, v, mask, 8, scale) * cot).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_row_mass_conservation():
    """Stored probability + implicit-zero mass must sum to 1 per row: check
    through the oracle's S^s plus reconstructed implicit mass."""
    rng = np.random.default_rng(11)
    l, dh, block = 32, 8, 8
    lb = l // block
    q = rng.standard_normal((l, dh), dtype=np.float32)
    k = rng.standard_normal((l, dh), dtype=np.float32)
    v = rng.standard_normal((l, dh), dtype=np.float32)
    bm = (rng.random((lb, lb)) < 0.5).astype(np.float32)
    np.fill_diagonal(bm, 1.0)
    p = np.asarray(ref.upsample_mask(jnp.asarray(bm), block))
    scale = 1.0 / np.sqrt(dh)
    _, s = ref.sparse_attention_scores_ref(q, k, v, p, scale)
    s = np.asarray(s)
    logits = (q @ k.T) * scale * p
    m = logits.max(-1, keepdims=True)
    denom = np.exp(logits - m).sum(-1, keepdims=True)
    implicit = (np.exp(-m) * (p == 0).sum(-1, keepdims=True) / denom).squeeze(-1)
    # stored + implicit-zero = 1 exactly: the pruned entries' exp(0-max)
    # terms were already counted inside denom because masked logits are 0.
    stored = s.sum(-1)
    np.testing.assert_allclose(stored + implicit, np.ones(l), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [4, 8])
def test_kernel_is_deterministic(block):
    q, k, v, mask = _mk_case(9, 2, 4, block, 8, 0.5)
    scale = 0.2
    a = np.asarray(_pallas_fwd(q, k, v, mask, block=block, scale=scale))
    b = np.asarray(_pallas_fwd(q, k, v, mask, block=block, scale=scale))
    np.testing.assert_array_equal(a, b)
