"""AOT pass tests: HLO text is emitted, parseable-looking, and the manifest
ABI is self-consistent."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model

jax.config.update("jax_platform_name", "cpu")

CFG = configs.ModelConfig("unit-aot", "listops", 64, 16, 2, 1, 32, 12, 4, 2)


def test_hlo_text_smoke():
    fns = model.jitted(CFG)
    lowered = fns["dense_fwd"].lower(
        [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in configs.param_specs(CFG)],
        jax.ShapeDtypeStruct((CFG.batch, CFG.seq_len), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: the root instruction is a tuple.
    assert "tuple(" in text


def test_manifest_io_contract():
    m = aot.manifest(CFG)
    assert m["preset"] == CFG.preset
    assert len(m["params"]) == 2 + 12 * CFG.layers + 2
    for art in ["init", "dense_step", "sparse_step", "dense_fwd", "sparse_fwd"]:
        assert art in m["io"], art
    assert m["lb"] * m["pattern_block"] == m["seq_len"]
    # JSON-serializable
    json.dumps(m)


def test_emit_preset_writes_files(tmp_path):
    aot.emit_preset(CFG, str(tmp_path), force=True)
    pdir = tmp_path / CFG.preset
    for art in ["init", "dense_step", "sparse_step", "dense_fwd", "sparse_fwd"]:
        f = pdir / f"{art}.hlo.txt"
        assert f.exists() and f.stat().st_size > 1000, art
    manifest = json.loads((pdir / "manifest.json").read_text())
    assert manifest["seq_len"] == CFG.seq_len


def test_emit_preset_is_incremental(tmp_path):
    aot.emit_preset(CFG, str(tmp_path), force=True)
    f = tmp_path / CFG.preset / "init.hlo.txt"
    t0 = f.stat().st_mtime_ns
    aot.emit_preset(CFG, str(tmp_path), force=False)
    assert f.stat().st_mtime_ns == t0, "unchanged artifacts must not be rewritten"


def test_golden_payloads_shape():
    pg = aot.pattern_golden_cases()
    assert len(pg["cases"]) >= 4
    for c in pg["cases"]:
        lb = c["l"] // c["block"]
        assert len(c["mask"]) == lb * lb
        assert len(c["pool_out"]) == lb * lb
        assert len(c["scores"]) == c["l"] ** 2
        # mask diagonal on
        m = np.array(c["mask"]).reshape(lb, lb)
        assert (np.diag(m) == 1).all()
    ag = aot.attention_golden_cases()
    for c in ag["cases"]:
        assert len(c["out"]) == c["l"] * c["dh"]
        assert len(c["s_sparse"]) == c["l"] * c["l"]


def test_default_presets_exist():
    for name in configs.DEFAULT_PRESETS:
        assert name in configs.BY_NAME
