"""L2 model tests: shapes, dense/sparse agreement, optimization sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

jax.config.update("jax_platform_name", "cpu")

CFG = configs.ModelConfig("unit", "listops", 64, 16, 2, 2, 32, 12, 4, 4)


@pytest.fixture(scope="module")
def fns():
    return model.jitted(CFG)


@pytest.fixture(scope="module")
def state(fns):
    params = fns["init"](np.uint32(0))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(0)
    x = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    y = rng.integers(0, CFG.classes, (CFG.batch,)).astype(np.int32)
    return params, m, v, x, y


def test_param_specs_count_matches_rust_formula():
    for cfg in configs.PRESETS + [CFG]:
        specs = configs.param_specs(cfg)
        assert len(specs) == 2 + 12 * cfg.layers + 2, cfg.preset


def test_init_shapes(state):
    params, *_ = state
    for p, (name, shape) in zip(params, configs.param_specs(CFG)):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_forward_shapes(fns, state):
    params, _, _, x, _ = state
    logits = fns["dense_fwd"](params, x)
    assert logits.shape == (CFG.batch, CFG.classes)
    masks = np.ones((CFG.layers, CFG.lb, CFG.lb), np.float32)
    logits_s = fns["sparse_fwd"](params, x, masks)
    assert logits_s.shape == (CFG.batch, CFG.classes)


def test_sparse_full_mask_equals_dense(fns, state):
    params, m, v, x, y = state
    out_d = fns["dense_step"](params, m, v, x, y, np.int32(1), np.float32(1e-3))
    masks = np.ones((CFG.layers, CFG.lb, CFG.lb), np.float32)
    out_s = fns["sparse_step"](params, m, v, x, y, np.int32(1), np.float32(1e-3), masks)
    np.testing.assert_allclose(float(out_d[3]), float(out_s[3]), rtol=1e-5)
    # updated params also agree
    for pd, ps in zip(out_d[0], out_s[0]):
        np.testing.assert_allclose(np.asarray(pd), np.asarray(ps), rtol=1e-4, atol=1e-5)


def test_scores_are_row_stochastic(fns, state):
    params, m, v, x, y = state
    *_, scores = fns["dense_step"](params, m, v, x, y, np.int32(1), np.float32(1e-3))
    assert scores.shape == (CFG.layers, CFG.seq_len, CFG.seq_len)
    sums = np.asarray(scores).sum(-1)
    np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-4)


def test_dense_training_reduces_loss(fns, state):
    params, m, v, x, y = state
    losses = []
    p, mm, vv = params, m, v
    for t in range(12):
        p, mm, vv, loss, _, _ = fns["dense_step"](p, mm, vv, x, y, np.int32(t + 1), np.float32(3e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_sparse_training_reduces_loss(fns, state):
    params, m, v, x, y = state
    rng = np.random.default_rng(1)
    masks = (rng.random((CFG.layers, CFG.lb, CFG.lb)) < 0.5).astype(np.float32)
    for n in range(CFG.layers):
        np.fill_diagonal(masks[n], 1.0)
    losses = []
    p, mm, vv = params, m, v
    for t in range(12):
        p, mm, vv, loss, _ = fns["sparse_step"](p, mm, vv, x, y, np.int32(t + 1), np.float32(3e-3), masks)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_adam_bias_correction_first_step():
    params = [jnp.ones((2, 2))]
    grads = [jnp.full((2, 2), 0.5)]
    m = [jnp.zeros((2, 2))]
    v = [jnp.zeros((2, 2))]
    new_p, _, _ = model.adam_update(params, grads, m, v, jnp.int32(1), 0.1)
    # With bias correction, the first update magnitude ≈ lr (sign-like).
    np.testing.assert_allclose(np.asarray(new_p[0]), np.ones((2, 2)) - 0.1, rtol=1e-3)


def test_deterministic_init():
    a = model.init_params(CFG, np.uint32(7))
    b = model.init_params(CFG, np.uint32(7))
    c = model.init_params(CFG, np.uint32(8))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert any(np.abs(np.asarray(x) - np.asarray(y)).max() > 1e-6 for x, y in zip(a, c))
