"""The `SPION_SPARSE_IMPL` lowering knob (EXPERIMENTS.md §Perf L2) must be a
pure performance choice: pallas-kernel and fused-ref lowerings of the sparse
model must produce identical numerics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, model

jax.config.update("jax_platform_name", "cpu")

CFG = configs.ModelConfig("impl", "listops", 64, 16, 2, 2, 32, 12, 4, 2)


def _fixture(seed=0):
    params = model.init_params(CFG, np.uint32(seed))
    rng = np.random.default_rng(seed)
    x = rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)).astype(np.int32)
    y = rng.integers(0, CFG.classes, (CFG.batch,)).astype(np.int32)
    masks = (rng.random((CFG.layers, CFG.lb, CFG.lb)) < 0.5).astype(np.float32)
    for n in range(CFG.layers):
        np.fill_diagonal(masks[n], 1.0)
    return params, x, y, masks


def _with_impl(impl, fn):
    old = model.SPARSE_IMPL
    model.SPARSE_IMPL = impl
    try:
        return fn()
    finally:
        model.SPARSE_IMPL = old


def test_fwd_lowerings_agree():
    params, x, _, masks = _fixture()
    a = _with_impl("pallas", lambda: model.sparse_fwd(CFG, params, x, masks))
    b = _with_impl("ref", lambda: model.sparse_fwd(CFG, params, x, masks))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_train_step_lowerings_agree():
    params, x, y, masks = _fixture(1)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]

    def step():
        return model.sparse_step(CFG, params, m, v, x, y, jnp.int32(1), jnp.float32(1e-3), masks)

    pa = _with_impl("pallas", step)
    rb = _with_impl("ref", step)
    np.testing.assert_allclose(float(pa[3]), float(rb[3]), rtol=1e-5)  # loss
    for t_p, t_r in zip(pa[0], rb[0]):  # updated params
        np.testing.assert_allclose(np.asarray(t_p), np.asarray(t_r), rtol=1e-3, atol=1e-5)
