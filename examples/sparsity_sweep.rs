//! Figure 7 regenerator (accuracy axis): SPION-C training across sparsity
//! ratios on the ListOps task — training time per step vs final quality.
//! (The pure-timing axis is `cargo bench --bench fig7_sparsity_sweep`;
//! this example produces the accuracy trade-off, which needs real runs.)
//!
//! Run: `cargo run --release --example sparsity_sweep -- --preset tiny \
//!        --steps 120 --ratios 0.70,0.80,0.90,0.96,0.99`

use anyhow::Result;
use spion::config::types::{preset, SparsityConfig};
use spion::config::{ExperimentConfig, PatternKind, TrainConfig};
use spion::coordinator::Trainer;
use spion::metrics::Phase;
use spion::pattern::SpionVariant;
use spion::runtime::Runtime;
use spion::util::bench::Report;
use spion::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    args.help_if_requested(
        "Fig. 7: sparsity-ratio sweep for SPION-C (training time + accuracy)",
        &[
            ("preset <name>", "model preset (default tiny)"),
            ("steps <n>", "steps per ratio (default 120)"),
            ("ratios <csv>", "sparsity ratios (default 0.70,0.80,0.90,0.96,0.99)"),
            ("out <path>", "CSV output (default results/fig7_accuracy.csv)"),
        ],
    );
    let preset_name = args.str_or("preset", "tiny");
    let (task, model) = preset(&preset_name).expect("unknown preset");
    let steps = args.usize_or("steps", 120);
    let ratios: Vec<f64> = args
        .str_or("ratios", "0.70,0.80,0.90,0.96,0.99")
        .split(',')
        .map(|s| s.trim().parse().expect("bad ratio"))
        .collect();

    let rt = Runtime::cpu()?;
    let mut report = Report::new(
        &format!("Fig. 7 — SPION-C sparsity sweep ({preset_name}, {steps} steps)"),
        &["sparsity ratio", "pattern density", "sparse step (ms)", "final loss", "eval acc"],
    );

    for &ratio in &ratios {
        let train = TrainConfig {
            steps,
            max_dense_steps: 30,
            min_dense_steps: 10,
            ..Default::default()
        };
        let exp = ExperimentConfig {
            task,
            model: model.clone(),
            train,
            sparsity: {
                let mut s =
                    SparsityConfig::for_model(PatternKind::Spion(SpionVariant::C), task, &model);
                s.pattern.alpha = ratio;
                s
            },
            exec: spion::exec::ExecConfig::with_workers(args.usize_or("workers", 1)),
            serve: Default::default(),
            http: Default::default(),
            obs: Default::default(),
            resil: Default::default(),
            artifacts_dir: args.str_or("artifacts", "artifacts"),
        };
        let trainer = Trainer::new(&rt, exp)?;
        let outcome = trainer.run()?;
        let m = &outcome.metrics;
        let density =
            m.pattern_density.iter().sum::<f64>() / m.pattern_density.len().max(1) as f64;
        println!(
            "ratio {ratio:.2}: density {density:.3}, final loss {:.4}, eval acc {:.4}",
            m.final_loss().unwrap_or(f32::NAN),
            m.eval_accuracy.unwrap_or(f64::NAN)
        );
        report.row(vec![
            format!("{:.0}%", ratio * 100.0),
            format!("{density:.3}"),
            format!("{:.1}", m.mean_step_ms(Phase::Sparse).unwrap_or(f64::NAN)),
            format!("{:.4}", m.final_loss().unwrap_or(f32::NAN)),
            format!("{:.4}", m.eval_accuracy.unwrap_or(f64::NAN)),
        ]);
    }
    report.print();
    report.save_csv(&args.str_or("out", "results/fig7_accuracy.csv"));
    Ok(())
}
