//! End-to-end driver (the DESIGN.md §6 validation run): full three-phase
//! SPION training on a real synthetic workload — through the AOT/PJRT
//! stack, or fully offline with `--backend native` (rust full-encoder
//! engine, no artifacts) — logging the loss curve and recording the run
//! for EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_e2e -- --preset listops \
//!        --kind cf --steps 300 --out results/train_e2e`
//!
//! The dense phase runs until the Frobenius criterion (Eq. 2) fires, the
//! per-layer patterns are generated with the convolutional flood fill, and
//! the sparse phase continues to the step budget. Output: metrics CSV,
//! pattern renders, a checkpoint, and a summary JSON.

use anyhow::Result;
use spion::config::types::{preset, SparsityConfig};
use spion::config::{ExperimentConfig, PatternKind, TrainBackend, TrainConfig};
use spion::coordinator::{NativeTrainer, Trainer};
use spion::runtime::Runtime;
use spion::util::cli::Args;
use spion::util::json::Json;

fn main() -> Result<()> {
    let args = Args::from_env();
    args.help_if_requested(
        "End-to-end three-phase SPION training",
        &[
            ("preset <name>", "model preset (tiny|image|listops|retrieval)"),
            ("kind <k>", "dense|bigbird|reformer|c|f|cf (default cf)"),
            ("backend <b>", "pjrt (AOT artifacts) | native (rust engine, offline)"),
            ("steps <n>", "total training steps (default 300)"),
            ("lr <f>", "learning rate (default 1e-3; Adam on pjrt, SGD+momentum on native)"),
            ("seed <n>", "run seed (default 42)"),
            ("workers <n>", "exec workers (0 = all cores; default 1 = serial)"),
            ("out <dir>", "output directory (default results/train_e2e)"),
        ],
    );
    let preset_name = args.str_or("preset", "listops");
    let (task, model) = preset(&preset_name).expect("unknown preset");
    let kind = PatternKind::parse(&args.str_or("kind", "cf")).expect("bad --kind");
    let d = TrainConfig::default();
    let backend_arg = args.str_or("backend", "pjrt");
    let train = TrainConfig {
        steps: args.usize_or("steps", 300),
        lr: args.f64_or("lr", 1e-3),
        momentum: spion::config::types::validate_momentum(args.f64_or("momentum", d.momentum))
            .map_err(|e| anyhow::anyhow!(e))?,
        backend: TrainBackend::parse(&backend_arg)
            .ok_or_else(|| anyhow::anyhow!("unknown --backend {backend_arg} (native|pjrt)"))?,
        seed: args.u64_or("seed", 42),
        max_dense_steps: args.usize_or("max-dense-steps", 60),
        ..d
    };
    let mut sparsity = SparsityConfig::for_model(kind, task, &model);
    sparsity.pattern.block = args.usize_or("block", sparsity.pattern.block);
    sparsity.pattern.alpha = args.f64_or("alpha", sparsity.pattern.alpha);
    sparsity.pattern.filter = args.usize_or("filter", sparsity.pattern.filter);
    let exec = spion::exec::ExecConfig {
        workers: args.usize_or("workers", 1),
        ..Default::default()
    };
    let exp = ExperimentConfig {
        task,
        model: model.clone(),
        train,
        sparsity,
        exec,
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        artifacts_dir: args.str_or("artifacts", "artifacts"),
    };
    let out_dir = args.str_or("out", "results/train_e2e");
    std::fs::create_dir_all(&out_dir)?;

    println!(
        "== train_e2e: preset={} kind={} backend={} steps={} L={} D={} H={} N={} batch={} workers={} ==",
        model.preset,
        exp.sparsity.kind.name(),
        exp.train.backend.name(),
        exp.train.steps,
        model.seq_len,
        model.d_model,
        model.heads,
        model.layers,
        model.batch,
        exp.exec.resolved_workers()
    );

    let kind_name = exp.sparsity.kind.name().to_string();
    let steps = exp.train.steps;
    let kind_tag = kind_name.to_lowercase().replace('-', "_");
    let ck_path = format!("{out_dir}/{}_{kind_tag}.ckpt", model.preset);
    let t0 = std::time::Instant::now();
    // Each backend saves through its own save_checkpoint so the example
    // writes byte-identical checkpoints to `spion train`.
    let outcome = match exp.train.backend {
        TrainBackend::Native => {
            let trainer = NativeTrainer::new(exp)?.verbose(true);
            let outcome = trainer.run()?;
            trainer.save_checkpoint(&outcome, &ck_path)?;
            outcome
        }
        TrainBackend::Pjrt => {
            let rt = Runtime::cpu()?;
            let trainer = Trainer::new(&rt, exp)?.verbose(true);
            let outcome = trainer.run()?;
            trainer.save_checkpoint(&outcome, &ck_path)?;
            outcome
        }
    };
    let wall = t0.elapsed().as_secs_f64();

    // --- outputs ---
    let csv_path = format!("{out_dir}/{}_{kind_tag}_loss.csv", model.preset);
    outcome.metrics.save(&csv_path)?;
    if let Some(masks) = &outcome.masks {
        for (n, m) in masks.iter().enumerate() {
            std::fs::write(format!("{out_dir}/{}_{kind_tag}_pattern_l{n}.txt", model.preset), m.render())?;
        }
    }

    let m = &outcome.metrics;
    let summary = Json::obj(vec![
        ("preset", Json::Str(model.preset.clone())),
        ("kind", Json::Str(kind_name.clone())),
        ("steps", Json::Num(steps as f64)),
        ("wall_s", Json::Num(wall)),
        ("transition_step", m.transition_step.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null)),
        ("pattern_density", Json::arr_f64(&m.pattern_density)),
        ("first_loss", Json::Num(m.records.first().map(|r| r.loss as f64).unwrap_or(f64::NAN))),
        ("final_loss", Json::Num(m.final_loss().unwrap_or(f32::NAN) as f64)),
        ("eval_accuracy", Json::Num(m.eval_accuracy.unwrap_or(f64::NAN))),
        (
            "dense_step_ms",
            m.mean_step_ms(spion::metrics::Phase::Dense).map(Json::Num).unwrap_or(Json::Null),
        ),
        (
            "sparse_step_ms",
            m.mean_step_ms(spion::metrics::Phase::Sparse).map(Json::Num).unwrap_or(Json::Null),
        ),
    ]);
    let summary_path = format!("{out_dir}/{}_{kind_tag}_summary.json", model.preset);
    std::fs::write(&summary_path, summary.to_string_pretty())?;

    println!("\n== summary ==");
    println!("{}", summary.to_string_pretty());
    println!("\nloss curve  → {csv_path}");
    println!("checkpoint  → {ck_path}");
    println!("summary     → {summary_path}");
    Ok(())
}
