//! Figure 1 + Figure 3 regenerator: train the dense phase for a few steps
//! on real task data, pull the per-layer head-averaged attention score
//! matrices A^s out of the training artifact, and render (a) the score
//! heatmaps and (b) the patterns each SPION variant extracts from them.
//!
//! Run: `cargo run --release --example pattern_viz -- --preset tiny --steps 15`

use anyhow::Result;
use spion::config::types::{default_block, preset};
use spion::coordinator::trainer::split_scores;
use spion::data::{batcher::Batcher, make_task};
use spion::pattern::spion::PatternConfig;
use spion::pattern::{generate_pattern, SpionVariant};
use spion::runtime::executor::lit;
use spion::runtime::{ArtifactSet, Runtime};
use spion::tensor::Mat;
use spion::util::cli::Args;

/// ASCII heatmap of a (downsampled) matrix: ' ' (low) → '█' (high).
fn heatmap(m: &Mat, target: usize) -> String {
    let ramp: Vec<char> = " .:-=+*#%@█".chars().collect();
    let step = (m.rows / target).max(1);
    let cells = m.rows / step;
    // Downsample by block mean.
    let mut vals = vec![0.0f32; cells * cells];
    for i in 0..cells {
        for j in 0..cells {
            let mut s = 0.0;
            for di in 0..step {
                for dj in 0..step {
                    s += m.at(i * step + di, j * step + dj);
                }
            }
            vals[i * cells + j] = s / (step * step) as f32;
        }
    }
    let max = vals.iter().cloned().fold(f32::MIN, f32::max).max(1e-9);
    let mut out = String::new();
    for i in 0..cells {
        for j in 0..cells {
            let t = (vals[i * cells + j] / max * (ramp.len() - 1) as f32) as usize;
            out.push(ramp[t.min(ramp.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

fn main() -> Result<()> {
    let args = Args::from_env();
    args.help_if_requested(
        "Render per-layer A^s heatmaps (Fig. 1) and SPION patterns (Fig. 3)",
        &[
            ("preset <name>", "model preset (default tiny)"),
            ("steps <n>", "dense warmup steps (default 15)"),
            ("alpha <f>", "pattern threshold quantile (default 0.9)"),
            ("out <dir>", "output dir (default results/pattern_viz)"),
        ],
    );
    let preset_name = args.str_or("preset", "tiny");
    let steps = args.usize_or("steps", 15);
    let alpha = args.f64_or("alpha", 0.9);
    let out_dir = args.str_or("out", "results/pattern_viz");
    std::fs::create_dir_all(&out_dir)?;

    let (task, model) = preset(&preset_name).expect("unknown preset");
    let rt = Runtime::cpu()?;
    let artifacts = ArtifactSet::open("artifacts", &preset_name)?;
    let m = &artifacts.manifest;
    let init = rt.load(&artifacts.path("init"))?;
    let dense_step = rt.load(&artifacts.path("dense_step"))?;

    // Dense warmup on real task data, keeping the last scores.
    let mut params = init.run(&[lit::scalar_u32(42)])?;
    let zeros: Vec<xla::Literal> = m
        .params
        .iter()
        .map(|p| {
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            lit::f32_vec(&vec![0.0; p.elements()], &dims).unwrap()
        })
        .collect();
    let (mut adam_m, mut adam_v) = (zeros.clone(), zeros);
    let mut batcher = Batcher::new(make_task(task, m.seq_len, m.vocab, m.classes), m.batch, 1);
    let mut scores = Vec::new();
    for step in 0..steps {
        let batch = batcher.next_batch();
        let mut inputs = Vec::new();
        inputs.extend(params.iter().cloned());
        inputs.extend(adam_m.iter().cloned());
        inputs.extend(adam_v.iter().cloned());
        inputs.push(lit::i32_vec(&batch.x, &[m.batch as i64, m.seq_len as i64])?);
        inputs.push(lit::i32_vec(&batch.y, &[m.batch as i64])?);
        inputs.push(lit::scalar_i32(step as i32 + 1));
        inputs.push(lit::scalar_f32(1e-3));
        let mut out = dense_step.run(&inputs)?;
        let p = m.param_count();
        let scores_lit = out.pop().unwrap();
        let _acc = out.pop();
        let loss = lit::scalar_to_f32(&out.pop().unwrap())?;
        adam_v = out.split_off(2 * p);
        adam_m = out.split_off(p);
        params = out;
        if step + 1 == steps {
            scores = split_scores(&scores_lit, m.layers, m.seq_len)?;
        }
        if step % 5 == 0 {
            println!("warmup step {step}: loss {loss:.4}");
        }
    }

    // Fig. 1: per-layer A^s heatmaps.
    let block = default_block(&model);
    for (n, a_s) in scores.iter().enumerate() {
        println!("\n=== layer {n}: head-averaged A^s (downsampled) ===");
        let hm = heatmap(a_s, 32);
        println!("{hm}");
        std::fs::write(format!("{out_dir}/{preset_name}_l{n}_scores.txt"), hm)?;
        // Full-resolution grayscale image of A^s (the actual Fig. 1 artifact).
        spion::util::pgm::save_pgm(a_s, &format!("{out_dir}/{preset_name}_l{n}_scores.pgm"))?;

        // Fig. 3: patterns per variant.
        for variant in [SpionVariant::C, SpionVariant::F, SpionVariant::CF] {
            let cfg = PatternConfig { variant, block, filter: 7, alpha };
            let mask = generate_pattern(a_s, &cfg);
            println!(
                "layer {n} {}: density {:.3} ({}/{} blocks)",
                variant.name(),
                mask.density(),
                mask.nnz_blocks(),
                mask.lb * mask.lb
            );
            let render = mask.render();
            if variant == SpionVariant::CF {
                println!("{render}");
            }
            std::fs::write(
                format!("{out_dir}/{preset_name}_l{n}_{}.txt", variant.name().to_lowercase()),
                render,
            )?;
        }
    }
    println!("wrote renders to {out_dir}/");
    Ok(())
}
