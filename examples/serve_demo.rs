//! Serving demo (Fig. 5 right-column analogue): batched inference through
//! the ticketed serving engine, dense vs SPION-sparse attention, reporting
//! latency/throughput.
//!
//! Each client thread *queues* its whole request chunk first — blocking
//! only on admission space (the bounded queue's backpressure), never on
//! results — then waits the tickets. The encoder is the rust-native
//! engine (no python, no XLA on the request path). Weights come from a
//! checkpoint if given (`--checkpoint` from train_e2e), else from the
//! artifact `init` function so the demo is runnable standalone.
//!
//! Run: `cargo run --release --example serve_demo -- --preset tiny \
//!        --requests 64 --concurrency 8 --queue-depth 128`

use anyhow::Result;
use spion::config::types::{preset, SparsityConfig};
use spion::config::{ExperimentConfig, PatternKind, TrainConfig};
use spion::coordinator::checkpoint::Checkpoint;
use spion::coordinator::trainer::generate_masks_for;
use spion::data::{batcher::Batcher, make_task};
use spion::model::{Encoder, ModelParams};
use spion::pattern::SpionVariant;
use spion::runtime::executor::lit;
use spion::runtime::{ArtifactSet, Runtime};
use spion::serve::{Engine, ServeConfig};
use spion::util::cli::Args;
use std::sync::Arc;
use std::time::Instant;

fn load_params(
    args: &Args,
    preset_name: &str,
    layers: usize,
) -> Result<(ModelParams, Option<Vec<spion::pattern::BlockMask>>)> {
    if let Some(ck_path) = args.get("checkpoint") {
        let ck = Checkpoint::load(ck_path)?;
        println!(
            "loaded checkpoint {ck_path} (step {}, {})",
            ck.step,
            if ck.masks.is_some() { "with trained masks" } else { "no masks" }
        );
        let params = ModelParams::from_checkpoint(&ck, layers)?;
        return Ok((params, ck.masks));
    }
    // Fall back to freshly-initialized weights via the AOT init artifact.
    let rt = Runtime::cpu()?;
    let artifacts = ArtifactSet::open("artifacts", preset_name)?;
    let init = rt.load(&artifacts.path("init"))?;
    let params = init.run(&[lit::scalar_u32(42)])?;
    let flat: Vec<(Vec<usize>, Vec<f32>)> = params
        .iter()
        .zip(&artifacts.manifest.params)
        .map(|(l, spec)| Ok((spec.shape.clone(), lit::to_f32_vec(l)?)))
        .collect::<Result<_>>()?;
    Ok((ModelParams::from_flat(&flat, layers)?, None))
}

fn run_load(
    name: &str,
    encoder: Encoder,
    tokens: &[Vec<i32>],
    concurrency: usize,
    cfg: ServeConfig,
) -> Result<(f64, f64)> {
    let engine = Arc::new(Engine::start(encoder, cfg)?);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    // div_ceil so a non-divisible request count still serves every request.
    for chunk in tokens.chunks(tokens.len().div_ceil(concurrency.max(1))) {
        let engine = engine.clone();
        let chunk: Vec<Vec<i32>> = chunk.to_vec();
        handles.push(std::thread::spawn(move || {
            // Queue everything (blocking on admission space only), then
            // wait the tickets — the non-blocking client path.
            let tickets: Vec<_> =
                chunk.into_iter().map(|t| engine.submit(t).expect("admitted")).collect();
            tickets
                .into_iter()
                .map(|t| t.wait().expect("response").class)
                .collect::<Vec<usize>>()
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let elapsed = t0.elapsed();
    let stats = engine.stats();
    let rps = stats.throughput_rps(elapsed);
    let lat = stats.mean_latency_ms();
    println!(
        "{name:<14} served {:>4} | mean latency {lat:>8.2} ms | p(max) {:>8.2} ms | {rps:>7.1} req/s | mean batch {:.1} | peak queue {}",
        all.len(),
        stats.max_latency_us.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e3,
        stats.mean_batch(),
        stats.queue_peak.load(std::sync::atomic::Ordering::Relaxed),
    );
    engine.shutdown();
    Ok((lat, rps))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    args.help_if_requested(
        "Batched-inference serving demo: dense vs SPION-sparse",
        &[
            ("preset <name>", "model preset (default tiny)"),
            ("checkpoint <path>", "checkpoint from train_e2e (default: fresh init)"),
            ("requests <n>", "total requests (default 64)"),
            ("concurrency <n>", "client threads (default 8)"),
            ("max-batch <n>", "batcher max batch (default 8)"),
            ("queue-depth <n>", "bounded admission depth (default 256)"),
            ("workers <n>", "engine pool workers (0 = all cores; default 1)"),
            ("kernel-workers <n>", "per-worker kernel parallelism for big L (default 1)"),
            ("deadline-us <n>", "per-request deadline, shed before execute (0 = none)"),
            ("alpha <f>", "SPION-CF threshold quantile (default 0.9)"),
        ],
    );
    let preset_name = args.str_or("preset", "tiny");
    let (task, model) = preset(&preset_name).expect("unknown preset");
    let n_requests = args.usize_or("requests", 64);
    let concurrency = args.usize_or("concurrency", 8);
    let serve_cfg = ServeConfig {
        queue_depth: args.usize_or("queue-depth", 256),
        max_batch: args.usize_or("max-batch", 8),
        max_wait_us: 2_000,
        workers: args.usize_or("workers", 1),
        kernel_workers: args.usize_or("kernel-workers", 1),
        deadline_us: args.u64_or("deadline-us", 0),
    };

    let (params, trained_masks) = load_params(&args, &preset_name, model.layers)?;

    // Request workload from the real task generator.
    let gen = make_task(task, model.seq_len, model.vocab, model.classes);
    let mut batcher = Batcher::new(gen, 1, 123);
    let tokens: Vec<Vec<i32>> = (0..n_requests).map(|_| batcher.next_batch().x).collect();

    println!(
        "== serve_demo: preset={preset_name} L={} D={} requests={n_requests} concurrency={concurrency} workers={}×{} queue_depth={} ==",
        model.seq_len,
        model.d_model,
        serve_cfg.resolved_workers(),
        serve_cfg.resolved_kernel_workers(),
        serve_cfg.queue_depth
    );

    // Dense serving.
    let dense_enc = Encoder::new(params.clone(), model.heads);
    let (lat_d, rps_d) = run_load("dense", dense_enc, &tokens, concurrency, serve_cfg)?;

    // SPION-CF sparse serving: the checkpoint's trained masks when present,
    // else a pattern from synthetic diagonal+vertical scores.
    let masks = match trained_masks {
        Some(ms) => {
            println!("sparse serving uses the checkpoint's trained masks");
            ms
        }
        None => {
            let exp = ExperimentConfig {
                task,
                model: model.clone(),
                train: TrainConfig::default(),
                sparsity: {
                    let mut s = SparsityConfig::for_model(
                        PatternKind::Spion(SpionVariant::CF),
                        task,
                        &model,
                    );
                    s.pattern.alpha = args.f64_or("alpha", s.pattern.alpha);
                    s
                },
                exec: Default::default(),
                serve: Default::default(),
                http: Default::default(),
                obs: Default::default(),
                resil: Default::default(),
                artifacts_dir: "artifacts".into(),
            };
            let mut rng = spion::util::rng::Rng::new(5);
            let scores: Vec<_> = (0..model.layers)
                .map(|_| {
                    spion::pattern::spion::synth_attention_scores(
                        model.seq_len, 1.0, 0.3, &[model.seq_len / 3], 0.05, &mut rng,
                    )
                })
                .collect();
            generate_masks_for(&exp, &scores)?
        }
    };
    let density: f64 = masks.iter().map(|m| m.density()).sum::<f64>() / masks.len() as f64;
    let sparse_enc = Encoder::new(params, model.heads).with_masks(masks)?;
    let (lat_s, rps_s) = run_load("spion-cf", sparse_enc, &tokens, concurrency, serve_cfg)?;

    println!(
        "\nsparse pattern density {density:.3} → latency {:.2}× lower, throughput {:.2}× higher",
        lat_d / lat_s,
        rps_s / rps_d
    );
    Ok(())
}
