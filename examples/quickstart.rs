//! Quickstart: the whole stack in one page.
//!
//! 1. Load the AOT artifacts for the `tiny` preset (build with
//!    `make artifacts`).
//! 2. Initialize parameters on the PJRT CPU client.
//! 3. Run one dense forward pass.
//! 4. Generate a SPION-CF sparsity pattern from synthetic attention scores
//!    and run the same batch through the sparse forward artifact.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use spion::config::types::preset;
use spion::coordinator::trainer::{generate_masks_for, masks_to_literal};
use spion::config::types::SparsityConfig;
use spion::config::{ExperimentConfig, PatternKind, TrainConfig};
use spion::data::{batcher::Batcher, make_task};
use spion::pattern::SpionVariant;
use spion::runtime::executor::lit;
use spion::runtime::Runtime;
use spion::util::rng::Rng;

fn main() -> Result<()> {
    let (task, model) = preset("tiny").expect("tiny preset");
    let exp = ExperimentConfig {
        task,
        model: model.clone(),
        train: TrainConfig::default(),
        // Block size must match the artifact-baked mask shape (manifest
        // `pattern_block`); `for_model` mirrors the AOT side.
        sparsity: SparsityConfig::for_model(PatternKind::Spion(SpionVariant::CF), task, &model),
        exec: Default::default(),
        serve: Default::default(),
        http: Default::default(),
        obs: Default::default(),
        resil: Default::default(),
        artifacts_dir: "artifacts".into(),
    };

    // --- runtime + artifacts ---
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let artifacts = spion::runtime::ArtifactSet::open("artifacts", "tiny")?;
    artifacts.manifest.check_against(&model)?;
    let init = rt.load(&artifacts.path("init"))?;
    let dense_fwd = rt.load(&artifacts.path("dense_fwd"))?;
    let sparse_fwd = rt.load(&artifacts.path("sparse_fwd"))?;

    // --- params + one batch ---
    let params = init.run(&[lit::scalar_u32(42)])?;
    println!("initialized {} parameter tensors", params.len());
    let mut batcher = Batcher::new(make_task(task, model.seq_len, model.vocab, model.classes), model.batch, 0);
    let batch = batcher.next_batch();
    let x = lit::i32_vec(&batch.x, &[model.batch as i64, model.seq_len as i64])?;

    // --- dense forward ---
    let mut inputs = params.clone();
    inputs.push(x.clone());
    let logits = lit::to_f32_vec(&dense_fwd.run(&inputs)?[0])?;
    println!("dense logits[0]  = {:?}", &logits[..model.classes]);

    // --- SPION-CF pattern + sparse forward ---
    let mut rng = Rng::new(7);
    let scores: Vec<_> = (0..model.layers)
        .map(|_| spion::pattern::spion::synth_attention_scores(model.seq_len, 1.0, 0.2, &[40], 0.05, &mut rng))
        .collect();
    let masks = generate_masks_for(&exp, &scores)?;
    for (n, m) in masks.iter().enumerate() {
        println!("layer {n}: pattern density {:.3} ({} of {} blocks)", m.density(), m.nnz_blocks(), m.lb * m.lb);
    }
    let mut inputs = params;
    inputs.push(x);
    inputs.push(masks_to_literal(&masks, model.layers, masks[0].lb)?);
    let slogits = lit::to_f32_vec(&sparse_fwd.run(&inputs)?[0])?;
    println!("sparse logits[0] = {:?}", &slogits[..model.classes]);
    println!("quickstart OK");
    Ok(())
}
